//! Empirical trials: time each candidate with short warmup + measure runs
//! and keep the fastest.
//!
//! Trials are deliberately much shorter than the paper's measurement
//! protocol (70 runs) — tuning happens on the serving path, so the budget
//! per candidate is a handful of kernel runs and the statistic is the
//! *minimum*, which is robust to scheduling noise at small sample sizes.
//! Each distinct (format, ordering, specialization) is converted exactly
//! once and reused across every (policy, threads) combination that names
//! it; RCM candidates share one reorder across all their formats, and
//! their timed iterations run through the [`PermutedOp`] wrapper so the
//! per-call vector permutation shows up in the measurement. Specialized
//! candidates run the registry micro-kernel their shape resolves to
//! ([`crate::kernels::specialize`]), so the generic-vs-specialized race
//! is settled by the same stopwatch as every other axis.
//!
//! Two levers keep the budget tight:
//!
//! * candidates are timed on the *workload being tuned* — an SpMM decision
//!   is measured on the fused SpMM kernel at the configured batch width,
//!   never extrapolated from SpMV timings;
//! * adaptive early termination — candidates are trialed in the
//!   [`CostModel`]'s predicted order (so the incumbent is strong early),
//!   and a candidate's timing loop stops once its best observation cannot
//!   plausibly catch the incumbent (the min statistic only improves with
//!   more samples, and timing noise adds time rather than removing it, so
//!   a [`Trialer::margin`]-wide gap after [`MIN_PROBE`] probes is final).

use std::time::Instant;

use crate::kernels::op::{ExecCtx, SpmvOp};
use crate::kernels::specialize::Specialization;
use crate::kernels::Workload;
use crate::sparse::gen::random_vector;
use crate::sparse::ordering::{apply_symmetric_permutation, rcm};
use crate::sparse::Csr;

use super::cost::CostModel;
use super::exec::{prepare, prepare_spec, PermutedOp};
use super::space::{Candidate, Format, Ordering};

/// Measured iterations before early termination may trigger: one probe can
/// catch a cold cache or a scheduler hiccup, two in a row cannot both be
/// flukes in the same direction.
pub const MIN_PROBE: usize = 2;

/// Default early-termination margin: a candidate more than 30% behind the
/// incumbent's best after [`MIN_PROBE`] probes is abandoned.
pub const DEFAULT_TRIAL_MARGIN: f64 = 1.3;

/// Timing of one candidate.
#[derive(Debug, Clone)]
pub struct TrialResult {
    /// The candidate measured.
    pub candidate: Candidate,
    /// Best observed seconds per kernel run.
    pub secs: f64,
    /// GFlop/s at `secs` (`2·nnz·k` flops).
    pub gflops: f64,
    /// One-time format conversion cost (amortized over reuse).
    pub convert_secs: f64,
    /// Registry micro-kernel the payload bound to (`None` for generic
    /// candidates — and for specialized ones whose shape turned out
    /// uncovered and degraded to the generic payload).
    pub variant: Option<&'static str>,
    /// Measured iterations actually run (less than `measure` when the
    /// early-termination budget cut the loop short).
    pub iters: usize,
}

/// The trial driver: warmup then measured iterations per candidate.
#[derive(Debug, Clone)]
pub struct Trialer {
    /// Untimed iterations per candidate.
    pub warmup: usize,
    /// Timed iterations per candidate (min is reported).
    pub measure: usize,
    /// Workload every candidate is timed on.
    pub workload: Workload,
    /// Early-termination margin: once a candidate's best observation
    /// exceeds `incumbent_best · margin` after [`MIN_PROBE`] probes, its
    /// remaining iterations are skipped. `f64::INFINITY` disables the
    /// cutoff (and the cost-model candidate ordering it relies on).
    pub margin: f64,
}

impl Default for Trialer {
    fn default() -> Self {
        Trialer {
            warmup: 2,
            measure: 8,
            workload: Workload::Spmv,
            margin: DEFAULT_TRIAL_MARGIN,
        }
    }
}

impl Trialer {
    /// Creates an SpMV trialer with explicit counts.
    pub fn new(warmup: usize, measure: usize) -> Trialer {
        Trialer { warmup, measure: measure.max(1), ..Trialer::default() }
    }

    /// The same trialer timing `workload` instead.
    pub fn with_workload(self, workload: Workload) -> Trialer {
        Trialer { workload, ..self }
    }

    /// The same trialer with an explicit early-termination margin
    /// (`f64::INFINITY` times every candidate fully, in the given order).
    pub fn with_margin(self, margin: f64) -> Trialer {
        Trialer { margin, ..self }
    }

    /// Times every candidate on the configured workload (formats converted
    /// once each). Kernels run on the persistent global
    /// [`crate::sched::WorkerPool`], so the timings measure steady-state
    /// execution, not thread-spawn latency. With a finite margin the
    /// candidates are trialed in the cost model's predicted order and
    /// hopeless timing loops are cut short; every candidate still gets a
    /// [`TrialResult`] (its `secs` is the min of the iterations it ran).
    pub fn run_all(&self, a: &Csr, candidates: &[Candidate]) -> Vec<TrialResult> {
        let workload = match self.workload {
            Workload::Spmm { k } => Workload::Spmm { k: k.max(1) },
            w => w,
        };
        let k = workload.k();
        let x = random_vector(a.ncols * k, 0x7e57_0001);
        let mut y = vec![0.0f64; a.nrows * k];
        let flops = workload.flops(a.nnz());
        let ordered: Vec<Candidate> = if self.margin.is_finite() && candidates.len() > 1 {
            // Conversion-free ordering: the trial loop below converts the
            // formats itself, so the ordering pass must not convert too.
            CostModel::new().ordering(a, candidates, workload)
        } else {
            candidates.to_vec()
        };
        // The RCM permutation (and the permuted matrix) is computed once
        // and shared by every RCM candidate, whatever its format — the
        // per-candidate one-time cost is then just the format conversion,
        // exactly like the natural-order side. The timed loop runs the
        // wrapped PermutedOp, so every measured iteration *includes* the
        // per-call vector gather/scatter a served request would pay:
        // trial timings reflect steady-state serving, not a bare kernel.
        let permuted: Option<(Vec<u32>, Csr)> =
            ordered.iter().any(|c| c.ordering == Ordering::Rcm).then(|| {
                let perm = rcm(a);
                let b = apply_symmetric_permutation(a, &perm);
                (perm, b)
            });
        type Payload<'m> = (Format, Ordering, Specialization, Box<dyn SpmvOp + 'm>, f64);
        let mut prepared: Vec<Payload<'_>> = Vec::new();
        let mut out = Vec::with_capacity(ordered.len());
        let mut incumbent = f64::INFINITY;
        // A specialized candidate's payload binds the registry
        // micro-kernel for its shape (falling back to the generic payload
        // when uncovered, which enumeration rules out anyway).
        fn prep<'m>(
            b: &'m Csr,
            format: Format,
            spec: Specialization,
            k: usize,
        ) -> Box<dyn SpmvOp + 'm> {
            match spec {
                Specialization::Specialized => {
                    prepare_spec(b, format, k).unwrap_or_else(|| prepare(b, format))
                }
                Specialization::Generic => prepare(b, format),
            }
        }
        for &cand in &ordered {
            if !prepared
                .iter()
                .any(|(f, o, s, _, _)| {
                    *f == cand.format && *o == cand.ordering && *s == cand.spec
                })
            {
                let t0 = Instant::now();
                let op: Box<dyn SpmvOp + '_> = match cand.ordering {
                    Ordering::Natural => prep(a, cand.format, cand.spec, k),
                    Ordering::Rcm => {
                        let (perm, b) = permuted.as_ref().expect("permuted matrix prepared");
                        Box::new(PermutedOp::new(
                            prep(b, cand.format, cand.spec, k),
                            perm.clone(),
                        ))
                    }
                };
                prepared.push((
                    cand.format,
                    cand.ordering,
                    cand.spec,
                    op,
                    t0.elapsed().as_secs_f64(),
                ));
            }
            let (_, _, _, op, convert_secs) = prepared
                .iter()
                .find(|(f, o, s, _, _)| {
                    *f == cand.format && *o == cand.ordering && *s == cand.spec
                })
                .unwrap();
            let ctx = ExecCtx::pooled(cand.threads, cand.policy);
            for _ in 0..self.warmup {
                op.apply(workload, &x, &mut y, &ctx);
                std::hint::black_box(&mut y);
            }
            let mut best = f64::INFINITY;
            let mut iters = 0usize;
            for _ in 0..self.measure.max(1) {
                let t0 = Instant::now();
                op.apply(workload, &x, &mut y, &ctx);
                std::hint::black_box(&mut y);
                best = best.min(t0.elapsed().as_secs_f64());
                iters += 1;
                if iters >= MIN_PROBE && best > incumbent * self.margin {
                    break;
                }
            }
            incumbent = incumbent.min(best);
            out.push(TrialResult {
                candidate: cand,
                secs: best,
                gflops: flops / best.max(1e-12) / 1e9,
                convert_secs: *convert_secs,
                variant: op.variant_name(),
                iters,
            });
        }
        out
    }

    /// Times every candidate and returns the fastest (`None` only for an
    /// empty candidate list).
    pub fn best(&self, a: &Csr, candidates: &[Candidate]) -> Option<TrialResult> {
        self.run_all(a, candidates)
            .into_iter()
            .min_by(|u, v| u.secs.partial_cmp(&v.secs).unwrap_or(std::cmp::Ordering::Equal))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Policy;
    use crate::sparse::gen::stencil::stencil_2d;
    use crate::sparse::MatrixStats;
    use crate::tuner::space::{enumerate, SpaceConfig};

    #[test]
    fn best_is_min_of_run_all() {
        let a = stencil_2d(25, 25);
        let candidates = [
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: Format::Ell,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
        ];
        let t = Trialer::new(1, 3);
        let all = t.run_all(&a, &candidates);
        assert_eq!(all.len(), 2);
        let best = t.best(&a, &candidates).unwrap();
        assert!(candidates.contains(&best.candidate), "best must come from the list");
        assert!(best.secs.is_finite() && best.secs >= 0.0);
        for r in &all {
            assert!(r.secs >= 0.0 && r.gflops >= 0.0);
            assert!(r.iters >= 1);
        }
    }

    #[test]
    fn rcm_candidates_trial_alongside_natural_ones() {
        let a = stencil_2d(20, 20);
        let candidates = [
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Rcm,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: Format::Ell,
                ordering: Ordering::Rcm,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
        ];
        let t = Trialer::new(0, 2).with_margin(f64::INFINITY);
        let results = t.run_all(&a, &candidates);
        assert_eq!(results.len(), 3);
        for r in &results {
            assert!(r.secs.is_finite() && r.secs >= 0.0, "{}", r.candidate);
        }
        let best = t.best(&a, &candidates).unwrap();
        assert!(candidates.contains(&best.candidate));
    }

    #[test]
    fn specialized_candidates_trial_and_record_their_variant() {
        let a = stencil_2d(20, 20);
        let generic = Candidate {
            format: Format::Csr,
            ordering: Ordering::Natural,
            policy: Policy::Dynamic(64),
            threads: 1,
            spec: Specialization::Generic,
        };
        let specialized = Candidate { spec: Specialization::Specialized, ..generic };
        let t = Trialer::new(0, 2).with_margin(f64::INFINITY);
        let results = t.run_all(&a, &[generic, specialized]);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].variant, None, "generic payloads carry no variant");
        let v = results[1].variant.expect("specialized CSR must bind a registry variant");
        assert!(v.starts_with("csr_u"), "{v}");
        for r in &results {
            assert!(r.secs.is_finite() && r.secs >= 0.0);
        }
    }

    #[test]
    fn empty_candidates_give_none() {
        let a = stencil_2d(10, 10);
        assert!(Trialer::default().best(&a, &[]).is_none());
    }

    #[test]
    fn trials_cover_a_real_space() {
        let a = stencil_2d(20, 20);
        let stats = MatrixStats::compute("s", &a);
        let space = enumerate(&a, &stats, &SpaceConfig::quick());
        let results = Trialer::new(0, 1).run_all(&a, &space.candidates);
        assert_eq!(results.len(), space.candidates.len());
        // Every input candidate appears exactly once, whatever the order.
        for cand in &space.candidates {
            assert_eq!(results.iter().filter(|r| r.candidate == *cand).count(), 1);
        }
    }

    #[test]
    fn spmm_trials_time_the_fused_kernel_at_the_batch_width() {
        let a = stencil_2d(20, 20);
        let sell = Format::Sell { c: 8, sigma: 64 };
        let candidates = [
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: sell,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
        ];
        let t = Trialer::new(0, 2).with_workload(Workload::Spmm { k: 4 });
        let results = t.run_all(&a, &candidates);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert!(r.secs.is_finite() && r.secs >= 0.0);
            // GFlop/s is computed over 2·nnz·k flops, so it must be
            // consistent with the recorded seconds.
            let implied = Workload::Spmm { k: 4 }.flops(a.nnz()) / r.secs.max(1e-12) / 1e9;
            assert!((implied - r.gflops).abs() <= 1e-9 * implied.abs().max(1.0));
        }
    }

    #[test]
    fn zero_margin_cuts_every_later_candidate_at_min_probe() {
        let a = stencil_2d(25, 25);
        let candidates = [
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(16),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: Format::Ell,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
        ];
        let measure = 6;
        let results = Trialer::new(0, measure).with_margin(0.0).run_all(&a, &candidates);
        assert_eq!(results.len(), 3);
        // The first trialed candidate faces an infinite incumbent and runs
        // fully; every later one is strictly worse than incumbent·0 and
        // stops right at the probe floor.
        assert_eq!(results[0].iters, measure);
        for r in &results[1..] {
            assert_eq!(r.iters, MIN_PROBE, "{}", r.candidate);
        }
    }

    #[test]
    fn infinite_margin_times_every_iteration_in_given_order() {
        let a = stencil_2d(25, 25);
        let candidates = [
            Candidate {
                format: Format::Ell,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(64),
                threads: 1,
                spec: Specialization::Generic,
            },
        ];
        let measure = 3;
        let results =
            Trialer::new(0, measure).with_margin(f64::INFINITY).run_all(&a, &candidates);
        assert_eq!(results.len(), 2);
        for (r, cand) in results.iter().zip(&candidates) {
            assert_eq!(r.candidate, *cand, "disabled budget must preserve order");
            assert_eq!(r.iters, measure);
        }
    }
}
