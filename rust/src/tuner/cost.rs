//! Analytic candidate ranking — the fallback when empirical trials are
//! disabled (e.g. tuning offline, or on a loaded host where timing is
//! meaningless).
//!
//! Reuses the paper-calibrated machinery: the CSR profile comes from
//! [`crate::kernels::spmv_model`] (`-O3` variant), BCSR from
//! [`crate::kernels::blocked_model`], and ELL/HYB are derived from the CSR
//! profile by scaling the instruction and stream-byte terms with the
//! padding blowup. Per-candidate scheduling is injected by recomputing the
//! load imbalance for the candidate's policy, and the thread count maps
//! onto the KNC model's cores × contexts grid. Absolute seconds are for a
//! KNC, not the host — only the *ranking* is consumed.

use crate::arch::phi::WorkProfile;
use crate::arch::PhiMachine;
use crate::kernels::blocked_model::bcsr_profile;
use crate::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use crate::sched::{LoadBalance, StaticAssignment};
use crate::sparse::ell::ELL_LANES;
use crate::sparse::{Bcsr, Csr};

use super::space::{Candidate, Format};

/// The analytic ranker.
pub struct CostModel {
    machine: PhiMachine,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { machine: PhiMachine::se10p() }
    }
}

impl CostModel {
    /// A cost model over the calibrated SE10P machine.
    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Ranks candidates by predicted time, ascending (best first).
    pub fn rank(&self, a: &Csr, candidates: &[Candidate]) -> Vec<(Candidate, f64)> {
        let analysis = SpmvAnalysis::compute(a, 61);
        let base = spmv_profile(a, SpmvVariant::O3, &analysis);
        let weights: Vec<u64> = (0..a.nrows).map(|i| a.row_nnz(i) as u64 + 4).collect();
        let mut out: Vec<(Candidate, f64)> = candidates
            .iter()
            .map(|&cand| {
                let mut w = self.profile_for(a, &base, cand.format);
                let assign = StaticAssignment::build(cand.policy, a.nrows, cand.threads.max(1));
                w.imbalance = LoadBalance::compute(&assign, &weights).imbalance;
                let (cores, contexts) = map_threads(cand.threads);
                let est = self.machine.estimate(cores, contexts, &w);
                (cand, est.time_s)
            })
            .collect();
        out.sort_by(|u, v| u.1.partial_cmp(&v.1).unwrap_or(std::cmp::Ordering::Equal));
        out
    }

    /// Predicted time for a single candidate (KNC seconds; ranking only).
    pub fn predict(&self, a: &Csr, candidate: Candidate) -> f64 {
        self.rank(a, &[candidate])[0].1
    }

    fn profile_for(&self, a: &Csr, base: &WorkProfile, format: Format) -> WorkProfile {
        let nnz = a.nnz() as f64;
        match format {
            Format::Csr => *base,
            Format::Ell => {
                // Padding inflates both the streamed matrix bytes and the
                // executed inner-loop iterations by the same factor. The
                // padded size is computed analytically (same rounding as
                // `Ell::from_csr`) — materializing the payload here could
                // allocate nrows × max_row slots just to read one scalar.
                let max_nnz = (0..a.nrows).map(|i| a.row_nnz(i)).max().unwrap_or(0);
                let width = max_nnz.max(1).div_ceil(ELL_LANES) * ELL_LANES;
                let padded = (a.nrows * width) as f64;
                let pad = padded / nnz.max(1.0);
                let mut w = *base;
                w.instructions = base.instructions * pad;
                w.stream_read_bytes = 12.0 * padded;
                w
            }
            Format::Bcsr { r, c } => bcsr_profile(a, &Bcsr::from_csr(a, r, c), 61),
            Format::Sell { c, sigma } => {
                // Same padding-scaling shape as ELL, but with SELL's much
                // smaller per-chunk padded size, computed analytically
                // (identical arithmetic to `Sell::from_csr`).
                let padded = crate::sparse::Sell::padded_len_for(a, c, sigma) as f64;
                let pad = padded / nnz.max(1.0);
                let mut w = *base;
                w.instructions = base.instructions * pad;
                w.stream_read_bytes = 12.0 * padded;
                w
            }
            Format::Hyb { width } => {
                // The overflow split happens at the raw width, but the
                // stored ELL part is lane-rounded exactly like the real
                // conversion (`Hyb::from_csr` → `Ell::from_csr`).
                let stored_width = width.max(1).div_ceil(ELL_LANES) * ELL_LANES;
                let padded = (a.nrows * stored_width) as f64;
                let tail: usize =
                    (0..a.nrows).map(|i| a.row_nnz(i).saturating_sub(width)).sum();
                let covered = (nnz - tail as f64).max(1.0);
                let pad = (padded / covered).min(8.0);
                let mut w = *base;
                // ELL part scaled by its own fill, plus a scalar COO pass
                // (~8 instructions and 16 streamed bytes per overflow entry).
                w.instructions = base.instructions * pad + 8.0 * tail as f64;
                w.stream_read_bytes = 12.0 * padded + 16.0 * tail as f64;
                w
            }
        }
    }
}

/// Maps a host thread count onto the KNC model's (cores, contexts) grid.
fn map_threads(threads: usize) -> (usize, usize) {
    let t = threads.max(1);
    (t.min(61), t.div_ceil(61).min(4))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Policy;
    use crate::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
    use crate::sparse::gen::stencil::stencil_2d;

    fn cand(format: Format, threads: usize) -> Candidate {
        Candidate { format, policy: Policy::Dynamic(64), threads }
    }

    #[test]
    fn rank_is_sorted_and_finite() {
        let a = stencil_2d(40, 40);
        let m = CostModel::new();
        let ranked = m.rank(
            &a,
            &[
                cand(Format::Csr, 4),
                cand(Format::Ell, 4),
                cand(Format::Bcsr { r: 8, c: 1 }, 4),
            ],
        );
        assert_eq!(ranked.len(), 3);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1, "must be ascending");
        }
        for (_, t) in &ranked {
            assert!(t.is_finite() && *t > 0.0);
        }
    }

    #[test]
    fn padding_blowup_penalizes_ell_on_skewed_rows() {
        // One hub row of 400 nonzeros forces ELL width 400 → the model must
        // rank CSR ahead of ELL.
        let a = powerlaw(&PowerLawSpec {
            n: 2000,
            nnz: 10_000,
            row_alpha: 1.6,
            col_alpha: 1.4,
            max_row: 400,
            seed: 3,
        });
        let m = CostModel::new();
        let csr = m.predict(&a, cand(Format::Csr, 8));
        let ell = m.predict(&a, cand(Format::Ell, 8));
        assert!(ell > csr, "ELL {ell} must lose to CSR {csr} under heavy padding");
    }

    #[test]
    fn sell_predicted_no_worse_than_ell_and_finite() {
        // SELL's padding is per-chunk, so on skewed rows it must never be
        // ranked behind ELL's global-width padding by the model.
        let a = powerlaw(&PowerLawSpec {
            n: 2000,
            nnz: 10_000,
            row_alpha: 1.6,
            col_alpha: 1.4,
            max_row: 400,
            seed: 3,
        });
        let m = CostModel::new();
        let ell = m.predict(&a, cand(Format::Ell, 8));
        let sell = m.predict(&a, cand(Format::Sell { c: 8, sigma: 256 }, 8));
        assert!(sell.is_finite() && sell > 0.0);
        assert!(sell <= ell, "SELL {sell} must not lose to ELL {ell} on skewed rows");
    }

    #[test]
    fn analytic_ell_padding_matches_real_conversion() {
        let a = stencil_2d(17, 23);
        let max_nnz = (0..a.nrows).map(|i| a.row_nnz(i)).max().unwrap_or(0);
        let width = max_nnz.max(1).div_ceil(ELL_LANES) * ELL_LANES;
        assert_eq!(a.nrows * width, crate::sparse::Ell::from_csr(&a, 0).padded_len());
    }

    #[test]
    fn more_threads_never_predicted_slower_on_uniform_work() {
        let a = stencil_2d(60, 60);
        let m = CostModel::new();
        let t1 = m.predict(&a, cand(Format::Csr, 1));
        let t8 = m.predict(&a, cand(Format::Csr, 8));
        assert!(t8 < t1, "8 threads {t8} vs serial {t1}");
    }

    #[test]
    fn static_predicted_worse_than_dynamic_on_skewed_rows() {
        let a = powerlaw(&PowerLawSpec {
            n: 3000,
            nnz: 12_000,
            row_alpha: 1.7,
            col_alpha: 1.4,
            max_row: 500,
            seed: 5,
        });
        let m = CostModel::new();
        let dynamic = m.predict(
            &a,
            Candidate { format: Format::Csr, policy: Policy::Dynamic(16), threads: 8 },
        );
        let stat = m.predict(
            &a,
            Candidate { format: Format::Csr, policy: Policy::StaticBlock, threads: 8 },
        );
        assert!(stat >= dynamic, "static {stat} vs dynamic {dynamic}");
    }
}
