//! Analytic candidate ranking — the fallback when empirical trials are
//! disabled (e.g. tuning offline, or on a loaded host where timing is
//! meaningless), and the ordering heuristic the trialer uses to meet an
//! incumbent early.
//!
//! Reuses the paper-calibrated machinery per [`Workload`]: the CSR SpMV
//! profile comes from [`crate::kernels::spmv_model`] (`-O3` variant), the
//! CSR SpMM profile from [`crate::kernels::spmm_model`] (the
//! compiler-vectorized `Generic` variant — what our host kernels are),
//! BCSR-under-SpMV from [`crate::kernels::blocked_model`], and the padded
//! formats are derived from the CSR profile by scaling the instruction and
//! stream-byte terms with the padding blowup. Per-candidate scheduling is
//! injected by recomputing the load imbalance for the candidate's policy,
//! and the thread count maps onto the KNC model's cores × contexts grid.
//! Absolute seconds are for a KNC, not the host — only the *ranking* is
//! consumed.
//!
//! The [`Ordering`] axis is ranked on *post-reorder* estimates: when any
//! candidate asks for RCM, the permuted matrix `P A Pᵀ` is materialized
//! once and the same gather/traffic analysis runs on it, so the model sees
//! exactly the cacheline locality the reorder buys (§4.4). RCM candidates
//! are then charged the per-call cost the [`crate::tuner::exec::PermutedOp`]
//! wrapper really pays — one gather of the input panel and one scatter of
//! the output panel per execution — so a matrix whose pattern barely
//! improves is never reordered for free.

use crate::arch::phi::WorkProfile;
use crate::arch::PhiMachine;
use crate::kernels::blocked_model::bcsr_profile;
use crate::kernels::specialize::Specialization;
use crate::kernels::spmm_model::{spmm_profile, SpmmAnalysis, SpmmVariant};
use crate::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use crate::kernels::{IsaLevel, Workload};
use crate::sched::{LoadBalance, StaticAssignment};
use crate::sparse::ell::ELL_LANES;
use crate::sparse::ordering::{apply_symmetric_permutation, rcm};
use crate::sparse::{Bcsr, Csr};

use super::space::{estimate_block_density, hyb_overflow_tail, Candidate, Format, Ordering};

/// Instruction-term multiplier for [`Specialization::Specialized`]
/// candidates: a const-shape micro-kernel retires the same FMAs but
/// sheds the runtime-parameter bookkeeping (trip-count arithmetic,
/// bounds-dependent branches, the per-block remainder logic the generic
/// loops re-test every iteration), so its instruction stream compresses
/// while its memory terms are byte-for-byte identical. The model prices
/// only that: compute-bound candidates gain, bandwidth-bound ones rank
/// unchanged — which is also why trials, not the model, settle the race.
pub const SPEC_INSTRUCTION_DISCOUNT: f64 = 0.75;

/// The analytic ranker.
pub struct CostModel {
    machine: PhiMachine,
    /// Host ISA the ranked kernels will actually run with: the
    /// instruction term of every profile is divided by its effective
    /// flop throughput, so compute-bound candidates compress toward
    /// their memory terms on wider vector units while bandwidth-bound
    /// ones rank unchanged.
    isa: IsaLevel,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { machine: PhiMachine::se10p(), isa: IsaLevel::detect() }
    }
}

impl CostModel {
    /// A cost model over the calibrated SE10P machine, at the detected
    /// host ISA.
    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// The same model pinned to a specific ISA level (tests; offline
    /// what-if ranking for a different host).
    pub fn with_isa(mut self, isa: IsaLevel) -> CostModel {
        self.isa = isa;
        self
    }

    /// Ranks SpMV candidates by predicted time, ascending (best first).
    pub fn rank(&self, a: &Csr, candidates: &[Candidate]) -> Vec<(Candidate, f64)> {
        self.rank_for(a, candidates, Workload::Spmv)
    }

    /// Ranks candidates for one workload by predicted time, ascending.
    pub fn rank_for(
        &self,
        a: &Csr,
        candidates: &[Candidate],
        workload: Workload,
    ) -> Vec<(Candidate, f64)> {
        self.rank_impl(a, candidates, workload, false)
    }

    /// Candidate *ordering* for the trialer's early-termination budget:
    /// same ranking machinery, but every format is profiled with the
    /// conversion-free analytic approximations (BCSR via
    /// [`estimate_block_density`] instead of the calibrated
    /// `bcsr_profile`, which converts the whole matrix; RCM via the
    /// natural-order base plus the per-call permutation charge, instead
    /// of materializing `P A Pᵀ`). The trialer converts, reorders and
    /// really times the candidates itself — it only needs a good order,
    /// and ordering must not cost a conversion or reorder the trial loop
    /// then repeats.
    pub fn ordering(
        &self,
        a: &Csr,
        candidates: &[Candidate],
        workload: Workload,
    ) -> Vec<Candidate> {
        self.rank_impl(a, candidates, workload, true)
            .into_iter()
            .map(|(cand, _)| cand)
            .collect()
    }

    fn rank_impl(
        &self,
        a: &Csr,
        candidates: &[Candidate],
        workload: Workload,
        cheap: bool,
    ) -> Vec<(Candidate, f64)> {
        let base = base_profile(a, workload);
        let weights = row_weights(a);
        // Post-reorder inputs, computed once when any candidate asks for
        // RCM: running the same gather/traffic analysis on the permuted
        // matrix *is* the post-reorder bandwidth estimate — the model sees
        // the locality the reorder actually produces, not a guess. The
        // cheap (trialer-ordering) mode skips this: the trial loop
        // materializes the reorder itself and must not pay for it twice,
        // so there RCM candidates reuse the natural base and are ranked by
        // their per-call permutation charge alone.
        let rcm_inputs: Option<(Csr, WorkProfile, Vec<u64>)> = (!cheap
            && candidates.iter().any(|c| c.ordering == Ordering::Rcm))
        .then(|| {
            let perm = rcm(a);
            let b = apply_symmetric_permutation(a, &perm);
            let rcm_base = base_profile(&b, workload);
            let rcm_weights = row_weights(&b);
            (b, rcm_base, rcm_weights)
        });
        // The format-dependent profile work is the expensive part (a BCSR
        // profile converts the matrix, SELL sorts row lengths) and depends
        // only on (format, ordering) — compute it once per distinct pair,
        // not once per (format, ordering, policy, threads) candidate.
        let mut profiles: Vec<((Format, Ordering), WorkProfile)> = Vec::new();
        let mut out: Vec<(Candidate, f64)> = candidates
            .iter()
            .map(|&cand| {
                let (aa, obase, oweights): (&Csr, &WorkProfile, &[u64]) = match cand.ordering {
                    Ordering::Natural => (a, &base, weights.as_slice()),
                    Ordering::Rcm => match rcm_inputs.as_ref() {
                        Some((b, rb, rw)) => (b, rb, rw.as_slice()),
                        // Cheap mode: natural base + the overhead charge
                        // below — good enough to order candidates, and the
                        // trialer times the real reordered kernel anyway.
                        None => (a, &base, weights.as_slice()),
                    },
                };
                let key = (cand.format, cand.ordering);
                if !profiles.iter().any(|(k2, _)| *k2 == key) {
                    let p = match workload {
                        // The cheap (ordering-only) SpMV arm swaps the
                        // conversion-backed BCSR profile for the density
                        // scaling the SpMM arm already uses.
                        Workload::Spmv => match cand.format {
                            Format::Bcsr { r, c } if cheap => {
                                let density = estimate_block_density(aa, r, c);
                                let pad =
                                    if density > 0.0 { (1.0 / density).min(8.0) } else { 1.0 };
                                let mut w = *obase;
                                w.instructions *= pad;
                                w.stream_read_bytes *= pad;
                                w
                            }
                            _ => self.profile_for(aa, obase, cand.format),
                        },
                        Workload::Spmm { k } => spmm_profile_for(aa, obase, cand.format, k.max(1)),
                    };
                    profiles.push((key, p));
                }
                let mut w = profiles.iter().find(|(k2, _)| *k2 == key).unwrap().1;
                if cand.ordering == Ordering::Rcm {
                    // What the PermutedOp wrapper pays per call: gather the
                    // x panel into permuted order and scatter the y panel
                    // back (~2 instructions per moved double, 8 B read +
                    // 8 B written each for both panels).
                    let moved = (a.nrows * workload.k()) as f64;
                    w.instructions += 4.0 * moved;
                    w.stream_read_bytes += 16.0 * moved;
                    w.write_bytes += 16.0 * moved;
                }
                let assign = StaticAssignment::build(cand.policy, aa.nrows, cand.threads.max(1));
                w.imbalance = LoadBalance::compute(&assign, oweights).imbalance;
                // Wider vector units retire the instruction stream
                // proportionally faster; the memory terms are untouched,
                // so bandwidth-bound candidates keep their ranking.
                w.instructions /= self.isa.flop_throughput();
                if cand.spec == Specialization::Specialized {
                    w.instructions *= SPEC_INSTRUCTION_DISCOUNT;
                }
                let (cores, contexts) = map_threads(cand.threads);
                let est = self.machine.estimate(cores, contexts, &w);
                (cand, est.time_s)
            })
            .collect();
        out.sort_by(|u, v| u.1.partial_cmp(&v.1).unwrap_or(std::cmp::Ordering::Equal));
        out
    }

    /// Predicted SpMV time for a single candidate (KNC seconds; ranking
    /// only).
    pub fn predict(&self, a: &Csr, candidate: Candidate) -> f64 {
        self.rank(a, &[candidate])[0].1
    }

    /// Predicted time for a single candidate under one workload.
    pub fn predict_for(&self, a: &Csr, candidate: Candidate, workload: Workload) -> f64 {
        self.rank_for(a, &[candidate], workload)[0].1
    }

    fn profile_for(&self, a: &Csr, base: &WorkProfile, format: Format) -> WorkProfile {
        match format {
            Format::Csr => *base,
            Format::Bcsr { r, c } => bcsr_profile(a, &Bcsr::from_csr(a, r, c), 61),
            _ => {
                let info = pad_info(a, format).expect("padded formats have pad info");
                let mut w = *base;
                // Padded part scaled by its fill, plus a scalar COO pass
                // for HYB's overflow (~8 instructions and 16 streamed
                // bytes per overflow entry; tail = 0 for ELL/SELL).
                w.instructions = base.instructions * info.pad + 8.0 * info.tail as f64;
                w.stream_read_bytes = 12.0 * info.padded + 16.0 * info.tail as f64;
                w
            }
        }
    }
}

/// The CSR base profile for one workload — the paper-calibrated analysis
/// the format scalings derive from. Run on the natural matrix and, for
/// RCM candidates, on the permuted one.
fn base_profile(a: &Csr, workload: Workload) -> WorkProfile {
    match workload {
        Workload::Spmv => {
            let analysis = SpmvAnalysis::compute(a, 61);
            spmv_profile(a, SpmvVariant::O3, &analysis)
        }
        Workload::Spmm { k } => {
            let analysis = SpmmAnalysis::compute(a, 61, k.max(1));
            spmm_profile(a, SpmmVariant::Generic, &analysis)
        }
    }
}

/// Row weights for the imbalance recomputation (nnz plus loop overhead).
fn row_weights(a: &Csr) -> Vec<u64> {
    (0..a.nrows).map(|i| a.row_nnz(i) as u64 + 4).collect()
}

/// Stored-slot accounting shared by both workload arms, so the SpMV and
/// SpMM rankings can never drift apart on what padding costs: padded slot
/// count, padding blowup relative to the nonzeros those slots cover, and
/// (HYB only) the serial overflow tail. The padded sizes are computed
/// analytically with the same rounding as the real conversions —
/// materializing an ELL payload here could allocate `nrows × max_row`
/// slots just to read one scalar. `None` for the unpadded CSR and for
/// BCSR, whose accounting differs per workload.
struct PadInfo {
    /// Stored slots of the padded part.
    padded: f64,
    /// Padding blowup: `padded / covered` nonzeros (capped at 8 for HYB).
    pad: f64,
    /// HYB overflow entries (0 for ELL/SELL).
    tail: usize,
}

fn pad_info(a: &Csr, format: Format) -> Option<PadInfo> {
    let nnz = a.nnz() as f64;
    match format {
        Format::Ell => {
            let max_nnz = (0..a.nrows).map(|i| a.row_nnz(i)).max().unwrap_or(0);
            let width = max_nnz.max(1).div_ceil(ELL_LANES) * ELL_LANES;
            let padded = (a.nrows * width) as f64;
            Some(PadInfo { padded, pad: padded / nnz.max(1.0), tail: 0 })
        }
        Format::Sell { c, sigma } => {
            let padded = crate::sparse::Sell::padded_len_for(a, c, sigma) as f64;
            Some(PadInfo { padded, pad: padded / nnz.max(1.0), tail: 0 })
        }
        Format::Hyb { width } => {
            // The overflow split happens at the raw width, but the stored
            // ELL part is lane-rounded exactly like the real conversion
            // (`Hyb::from_csr` → `Ell::from_csr`).
            let stored_width = width.max(1).div_ceil(ELL_LANES) * ELL_LANES;
            let padded = (a.nrows * stored_width) as f64;
            let tail = hyb_overflow_tail(a, width);
            let covered = (nnz - tail as f64).max(1.0);
            Some(PadInfo { padded, pad: (padded / covered).min(8.0), tail })
        }
        Format::Csr | Format::Bcsr { .. } => None,
    }
}

/// Format scaling of the SpMM base profile: padded formats execute (and
/// stream) the padding's extra slots, each now `k` FMAs wide, so the
/// [`pad_info`] blowup applies to both the instruction and the stream-byte
/// terms; HYB additionally pays its serial COO tail k-wide. BCSR's blowup
/// comes from [`estimate_block_density`] instead of a full conversion.
fn spmm_profile_for(a: &Csr, base: &WorkProfile, format: Format, k: usize) -> WorkProfile {
    match format {
        Format::Csr => *base,
        Format::Bcsr { r, c } => {
            let density = estimate_block_density(a, r, c);
            let pad = if density > 0.0 { (1.0 / density).min(8.0) } else { 1.0 };
            let mut w = *base;
            w.instructions = base.instructions * pad;
            w.stream_read_bytes = base.stream_read_bytes * pad;
            w
        }
        _ => {
            let info = pad_info(a, format).expect("padded formats have pad info");
            let mut w = *base;
            // Serial k-wide COO pass for HYB's overflow: ~2 instructions
            // per produced value plus per-entry overhead, and 16
            // index/value bytes + one k-wide X row per overflow entry
            // (tail = 0 for ELL/SELL).
            w.instructions =
                base.instructions * info.pad + (6.0 + 2.0 * k as f64) * info.tail as f64;
            w.stream_read_bytes =
                base.stream_read_bytes * info.pad + (16.0 + 8.0 * k as f64) * info.tail as f64;
            w
        }
    }
}

/// Maps a host thread count onto the KNC model's (cores, contexts) grid.
fn map_threads(threads: usize) -> (usize, usize) {
    let t = threads.max(1);
    (t.min(61), t.div_ceil(61).min(4))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Policy;
    use crate::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
    use crate::sparse::gen::stencil::stencil_2d;

    fn cand(format: Format, threads: usize) -> Candidate {
        Candidate {
            format,
            ordering: Ordering::Natural,
            policy: Policy::Dynamic(64),
            threads,
            spec: Specialization::Generic,
        }
    }

    #[test]
    fn rank_is_sorted_and_finite() {
        let a = stencil_2d(40, 40);
        let m = CostModel::new();
        let ranked = m.rank(
            &a,
            &[
                cand(Format::Csr, 4),
                cand(Format::Ell, 4),
                cand(Format::Bcsr { r: 8, c: 1 }, 4),
            ],
        );
        assert_eq!(ranked.len(), 3);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1, "must be ascending");
        }
        for (_, t) in &ranked {
            assert!(t.is_finite() && *t > 0.0);
        }
    }

    #[test]
    fn padding_blowup_penalizes_ell_on_skewed_rows() {
        // One hub row of 400 nonzeros forces ELL width 400 → the model must
        // rank CSR ahead of ELL.
        let a = powerlaw(&PowerLawSpec {
            n: 2000,
            nnz: 10_000,
            row_alpha: 1.6,
            col_alpha: 1.4,
            max_row: 400,
            seed: 3,
        });
        let m = CostModel::new();
        let csr = m.predict(&a, cand(Format::Csr, 8));
        let ell = m.predict(&a, cand(Format::Ell, 8));
        assert!(ell > csr, "ELL {ell} must lose to CSR {csr} under heavy padding");
    }

    #[test]
    fn sell_predicted_no_worse_than_ell_and_finite() {
        // SELL's padding is per-chunk, so on skewed rows it must never be
        // ranked behind ELL's global-width padding by the model.
        let a = powerlaw(&PowerLawSpec {
            n: 2000,
            nnz: 10_000,
            row_alpha: 1.6,
            col_alpha: 1.4,
            max_row: 400,
            seed: 3,
        });
        let m = CostModel::new();
        let ell = m.predict(&a, cand(Format::Ell, 8));
        let sell = m.predict(&a, cand(Format::Sell { c: 8, sigma: 256 }, 8));
        assert!(sell.is_finite() && sell > 0.0);
        assert!(sell <= ell, "SELL {sell} must not lose to ELL {ell} on skewed rows");
    }

    #[test]
    fn analytic_ell_padding_matches_real_conversion() {
        let a = stencil_2d(17, 23);
        let max_nnz = (0..a.nrows).map(|i| a.row_nnz(i)).max().unwrap_or(0);
        let width = max_nnz.max(1).div_ceil(ELL_LANES) * ELL_LANES;
        assert_eq!(a.nrows * width, crate::sparse::Ell::from_csr(&a, 0).padded_len());
    }

    #[test]
    fn more_threads_never_predicted_slower_on_uniform_work() {
        let a = stencil_2d(60, 60);
        let m = CostModel::new();
        let t1 = m.predict(&a, cand(Format::Csr, 1));
        let t8 = m.predict(&a, cand(Format::Csr, 8));
        assert!(t8 < t1, "8 threads {t8} vs serial {t1}");
    }

    #[test]
    fn spmm_rank_is_sorted_finite_and_padding_aware() {
        let a = powerlaw(&PowerLawSpec {
            n: 2000,
            nnz: 10_000,
            row_alpha: 1.6,
            col_alpha: 1.4,
            max_row: 400,
            seed: 3,
        });
        let m = CostModel::new();
        let w = Workload::Spmm { k: 16 };
        let ranked = m.rank_for(
            &a,
            &[
                cand(Format::Csr, 8),
                cand(Format::Ell, 8),
                cand(Format::Sell { c: 8, sigma: 256 }, 8),
            ],
            w,
        );
        assert_eq!(ranked.len(), 3);
        for pair in ranked.windows(2) {
            assert!(pair[0].1 <= pair[1].1, "must be ascending");
        }
        for (_, t) in &ranked {
            assert!(t.is_finite() && *t > 0.0);
        }
        // Padding penalties carry over to the SpMM profiles.
        let csr = m.predict_for(&a, cand(Format::Csr, 8), w);
        let ell = m.predict_for(&a, cand(Format::Ell, 8), w);
        assert!(ell > csr, "ELL {ell} must lose to CSR {csr} under heavy padding");
    }

    #[test]
    fn rcm_predicted_faster_on_scrambled_band_slower_on_intact_band() {
        // A banded matrix scrambled by a random symmetric permutation:
        // the post-reorder analysis must see the recovered locality and
        // rank the RCM candidate ahead of natural order.
        let a = crate::sparse::gen::banded::banded_runs(&crate::sparse::gen::banded::BandedSpec {
            n: 1500,
            mean_row: 10.0,
            run: 4,
            locality: 0.01,
            seed: 11,
        });
        let mut rng = crate::sparse::gen::Rng::new(23);
        let mut shuffle: Vec<u32> = (0..a.nrows as u32).collect();
        for i in (1..a.nrows).rev() {
            let j = rng.usize_below(i + 1);
            shuffle.swap(i, j);
        }
        let scrambled = apply_symmetric_permutation(&a, &shuffle);
        let m = CostModel::new();
        let rcm_cand = Candidate { ordering: Ordering::Rcm, ..cand(Format::Csr, 8) };
        for w in [Workload::Spmv, Workload::Spmm { k: 8 }] {
            let natural = m.predict_for(&scrambled, cand(Format::Csr, 8), w);
            let reordered = m.predict_for(&scrambled, rcm_cand, w);
            assert!(
                reordered < natural,
                "{w}: rcm {reordered} must beat natural {natural} on a scrambled band"
            );
            // On the intact band RCM has nothing to recover, so the
            // per-call permutation overhead must keep natural ahead.
            let natural = m.predict_for(&a, cand(Format::Csr, 8), w);
            let reordered = m.predict_for(&a, rcm_cand, w);
            assert!(
                reordered > natural,
                "{w}: rcm {reordered} must pay overhead vs natural {natural} on an intact band"
            );
        }
    }

    #[test]
    fn ordering_is_a_permutation_of_the_candidates() {
        let a = stencil_2d(30, 30);
        let cands = [
            cand(Format::Csr, 4),
            cand(Format::Bcsr { r: 8, c: 1 }, 4),
            cand(Format::Ell, 1),
        ];
        for w in [Workload::Spmv, Workload::Spmm { k: 8 }] {
            let ordered = CostModel::new().ordering(&a, &cands, w);
            assert_eq!(ordered.len(), cands.len());
            for c in &cands {
                assert!(ordered.contains(c), "{c} missing from ordering under {w}");
            }
        }
    }

    #[test]
    fn hyb_serial_tail_penalized_under_wide_spmm() {
        // Hub-heavy rows overflow HYB's ELL width; the serial COO tail is
        // charged k-wide, so HYB must fall behind CSR as k grows.
        let a = powerlaw(&PowerLawSpec {
            n: 2000,
            nnz: 10_000,
            row_alpha: 1.6,
            col_alpha: 1.4,
            max_row: 400,
            seed: 3,
        });
        let m = CostModel::new();
        let hyb = cand(Format::Hyb { width: 8 }, 8);
        let csr = cand(Format::Csr, 8);
        let w = Workload::Spmm { k: 32 };
        assert!(
            m.predict_for(&a, hyb, w) > m.predict_for(&a, csr, w),
            "k=32 HYB must lose to CSR on an overflow-heavy matrix"
        );
    }

    #[test]
    fn wider_isa_never_predicted_slower() {
        let a = stencil_2d(50, 50);
        let c = cand(Format::Csr, 8);
        for w in [Workload::Spmv, Workload::Spmm { k: 8 }] {
            let portable = CostModel::new().with_isa(IsaLevel::Portable).predict_for(&a, c, w);
            let avx2 = CostModel::new().with_isa(IsaLevel::Avx2).predict_for(&a, c, w);
            let avx512 = CostModel::new().with_isa(IsaLevel::Avx512).predict_for(&a, c, w);
            assert!(
                portable >= avx2 && avx2 >= avx512,
                "{w}: predicted times must not grow with vector width \
                 ({portable} / {avx2} / {avx512})"
            );
            assert!(avx512 > 0.0 && avx512.is_finite());
        }
    }

    #[test]
    fn specialized_twin_predicted_faster_never_slower() {
        // The discount shrinks only the instruction term under a roofline
        // max(), so a specialized candidate is never predicted slower than
        // its generic twin, never gains more than the discount itself, and
        // gains strictly wherever the twin was compute-bound.
        let a = stencil_2d(40, 40);
        let m = CostModel::new();
        let mut strict_win = false;
        for w in [Workload::Spmv, Workload::Spmm { k: 8 }] {
            for (format, threads) in
                [(Format::Csr, 1), (Format::Csr, 8), (Format::Bcsr { r: 4, c: 4 }, 8)]
            {
                let generic = cand(format, threads);
                let spec = Candidate { spec: Specialization::Specialized, ..generic };
                let tg = m.predict_for(&a, generic, w);
                let ts = m.predict_for(&a, spec, w);
                assert!(ts <= tg, "{w} {format} t{threads}: specialized {ts} vs generic {tg}");
                assert!(
                    ts >= tg * SPEC_INSTRUCTION_DISCOUNT,
                    "{w} {format} t{threads}: discount only touches the instruction term"
                );
                strict_win |= ts < tg;
            }
        }
        assert!(strict_win, "at least one compute-bound twin must gain from the discount");
    }

    #[test]
    fn static_predicted_worse_than_dynamic_on_skewed_rows() {
        let a = powerlaw(&PowerLawSpec {
            n: 3000,
            nnz: 12_000,
            row_alpha: 1.7,
            col_alpha: 1.4,
            max_row: 500,
            seed: 5,
        });
        let m = CostModel::new();
        let dynamic = m.predict(
            &a,
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::Dynamic(16),
                threads: 8,
                spec: Specialization::Generic,
            },
        );
        let stat = m.predict(
            &a,
            Candidate {
                format: Format::Csr,
                ordering: Ordering::Natural,
                policy: Policy::StaticBlock,
                threads: 8,
                spec: Specialization::Generic,
            },
        );
        assert!(stat >= dynamic, "static {stat} vs dynamic {dynamic}");
    }
}
