//! Format ablation: CRS vs ELL vs JDS vs DIA vs HYB vs dense-BCSR vs
//! bitmap-BCSR — storage bytes and measured SpMV throughput per class of
//! matrix. Completes the paper's §3/§4.5 storage-scheme discussion with
//! the bitmap variant it proposes as future work.
//!
//! `cargo bench --bench bench_formats [-- --scale 0.05]`

use phi_spmv::sparse::alt_formats::{Dia, Hyb, Jds};
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::sparse::{Bcsr, BitmapBcsr, Ell};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let bencher = Bencher::quick();
    let suite = paper_suite();

    // stencil (DIA-friendly), FEM (BCSR-friendly), web (ELL-hostile).
    for idx in [19usize, 5, 7] {
        let e = &suite[idx];
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let x = random_vector(a.ncols, 61);
        let flops = 2.0 * a.nnz() as f64;
        let want = a.spmv(&x);
        let check = |y: &[f64]| {
            y.iter().zip(&want).all(|(u, v)| (u - v).abs() < 1e-9 * (1.0 + v.abs()))
        };

        println!("== {} ({} rows, {} nnz) ==", e.name, a.nrows, a.nnz());
        println!("{:<14} {:>14} {:>12} {:>8}", "format", "bytes", "GFlop/s", "ok");

        let m = bencher.run("csr", || a.spmv(&x));
        println!("{:<14} {:>14} {:>12.3} {:>8}", "csr", a.storage_bytes(), m.gflops(flops), "ref");

        let ell = Ell::from_csr(&a, 0);
        let m = bencher.run("ell", || ell.spmv(&x));
        println!(
            "{:<14} {:>14} {:>12.3} {:>8}",
            format!("ell w{}", ell.width),
            ell.padded_len() * 12,
            m.gflops(flops),
            check(&ell.spmv(&x))
        );

        let jds = Jds::from_csr(&a);
        let m = bencher.run("jds", || jds.spmv(&x));
        println!(
            "{:<14} {:>14} {:>12.3} {:>8}",
            "jds",
            jds.vals.len() * 12 + jds.perm.len() * 4,
            m.gflops(flops),
            check(&jds.spmv(&x))
        );

        match Dia::from_csr(&a, 64) {
            Some(dia) => {
                let m = bencher.run("dia", || dia.spmv(&x));
                println!(
                    "{:<14} {:>14} {:>12.3} {:>8}",
                    format!("dia d{}", dia.offsets.len()),
                    dia.stored() * 8,
                    m.gflops(flops),
                    check(&dia.spmv(&x))
                );
            }
            None => println!("{:<14} {:>14} {:>12} {:>8}", "dia", "overflow", "-", "-"),
        }

        let hyb = Hyb::from_csr(&a, 8);
        let m = bencher.run("hyb", || hyb.spmv(&x));
        println!(
            "{:<14} {:>14} {:>12.3} {:>8}",
            format!("hyb {:.0}%ell", 100.0 * hyb.regular_fraction(a.nnz())),
            hyb.ell.padded_len() * 12 + hyb.coo.nnz() * 16,
            m.gflops(flops),
            check(&hyb.spmv(&x))
        );

        for (r, c) in [(8usize, 8usize), (8, 1)] {
            let b = Bcsr::from_csr(&a, r, c);
            let m = bencher.run("bcsr", || b.spmv(&x));
            println!(
                "{:<14} {:>14} {:>12.3} {:>8}",
                format!("bcsr {r}x{c}"),
                b.storage_bytes(),
                m.gflops(flops),
                check(&b.spmv(&x))
            );
            let bb = BitmapBcsr::from_csr(&a, r, c);
            let m = bencher.run("bitmap", || bb.spmv(&x));
            println!(
                "{:<14} {:>14} {:>12.3} {:>8}",
                format!("bitmap {r}x{c}"),
                bb.storage_bytes(),
                m.gflops(flops),
                check(&bb.spmv(&x))
            );
        }
        println!();
    }
}
