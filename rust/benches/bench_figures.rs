//! End-to-end figure regeneration timing: runs every paper experiment at
//! bench scale and reports wall time per figure (the coordinator's own
//! hot path — matrix generation + analyses dominate).
//!
//! `cargo bench --bench bench_figures [-- --scale 0.02]`

use phi_spmv::coordinator::{Ctx, Experiment, ALL_EXPERIMENTS};
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let ctx = Ctx {
        scale: args.get("scale", 0.02f64),
        out_dir: std::env::temp_dir().join("phi-spmv-bench-figures"),
        verbose: false,
        ..Ctx::default()
    };
    println!("scale {} → {}", ctx.scale, ctx.out_dir.display());
    let mut total = 0.0;
    for id in ALL_EXPERIMENTS {
        let t0 = std::time::Instant::now();
        let r = Experiment::run(id, &ctx).expect("experiment");
        r.save(&ctx.out_dir).expect("save");
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("{id:<8} {dt:>8.2}s  ({} tables)", r.tables.len());
    }
    println!("total    {total:>8.2}s");
}
