//! Tuned configuration vs. the default CSR dynamic,64 baseline across the
//! generator suite — the payoff measurement for the tuner subsystem.
//!
//! For each matrix class we report the default, the tuned pick, and the
//! best/worst candidates the search saw, so the table shows both the win
//! over the default and that the tuner never lands on a loser.
//!
//! `cargo bench --bench bench_autotune [-- --scale 0.05]`

use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::sparse::MatrixStats;
use phi_spmv::tuner::space::{enumerate, SpaceConfig};
use phi_spmv::tuner::{Trialer, Tuner, TunerConfig, TuningCache};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64).clamp(1e-4, 1.0);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let bencher = Bencher::quick();
    let suite = paper_suite();

    println!(
        "{:<16} {:>6} {:>9} | {:>12} {:>12} {:>12} {:>12} | {:<22} {:>6}",
        "matrix", "cands", "tune_ms", "default", "tuned", "best_cand", "worst_cand", "decision",
        "ok"
    );

    // Quad mesh, scattered circuit, power-law web, FEM, 2D stencil.
    for idx in [0usize, 2, 7, 11, 19] {
        let entry = &suite[idx];
        let mut a = entry.generate_scaled(scale);
        randomize_values(&mut a, entry.id as u64);
        let x = random_vector(a.ncols, 61);
        let flops = 2.0 * a.nnz() as f64;

        // Baseline: the configuration every experiment in the paper
        // defaults to (CSR, dynamic,64, all threads).
        let baseline = bencher.run("default", || {
            phi_spmv::kernels::spmv_parallel(&a, &x, threads, Policy::Dynamic(64))
        });

        // The tuner's decision (its own short trials, in-memory cache).
        let mut tuner = Tuner::new(TunerConfig::default(), TuningCache::in_memory());
        let t0 = std::time::Instant::now();
        let decision = tuner.tune(entry.name, &a).expect("tuning failed");
        let tune_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Re-measure the tuned pick with the same protocol as the baseline.
        let prepared = phi_spmv::tuner::Prepared::new(&a, decision.candidate());
        let tuned = bencher.run("tuned", || prepared.spmv(&x));

        // Sweep the whole candidate space once more to locate the
        // best/worst envelope the search chose from. The envelope must
        // fully time every candidate, so the early-termination budget is
        // disabled (an infinite margin also preserves the given order).
        let stats = MatrixStats::compute(entry.name, &a);
        let space = enumerate(&a, &stats, &SpaceConfig::default());
        let results =
            Trialer::default().with_margin(f64::INFINITY).run_all(&a, &space.candidates);
        let best = results.iter().map(|r| r.gflops).fold(0.0f64, f64::max);
        let worst = results.iter().map(|r| r.gflops).fold(f64::INFINITY, f64::min);

        // Acceptance: the tuned config must never be slower than the worst
        // candidate in its own space (10% timing-noise allowance).
        let tuned_gflops = tuned.gflops(flops);
        let ok = tuned_gflops >= worst * 0.9;
        if !ok {
            eprintln!(
                "WARN {}: tuned {tuned_gflops:.3} GFlop/s below worst candidate {worst:.3}",
                entry.name
            );
        }
        println!(
            "{:<16} {:>6} {:>9.1} | {:>9.3} GF {:>9.3} GF {:>9.3} GF {:>9.3} GF | {:<22} {:>6}",
            entry.name,
            space.candidates.len(),
            tune_ms,
            baseline.gflops(flops),
            tuned_gflops,
            best,
            worst,
            format!("{} {} t{}", decision.format, decision.policy, decision.threads),
            ok
        );
    }
}
