//! Tuned configuration vs. the default CSR dynamic,64 baseline across the
//! generator suite — the payoff measurement for the tuner subsystem.
//!
//! For each matrix class we report the default, the tuned pick (full
//! search space, ordering axis included), the tuned pick with the
//! ordering axis pinned to natural order, and the best/worst candidates
//! the search saw — so the table shows the win over the default, what the
//! RCM axis adds on matrices whose pattern strays from the diagonal (a
//! scrambled band rides along as the showcase), and that the tuner never
//! lands on a loser. The same numbers are written to
//! `BENCH_autotune.json`.
//!
//! `cargo bench --bench bench_autotune [-- --scale 0.05]`

use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::banded::{banded_runs, BandedSpec};
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values, Rng};
use phi_spmv::sparse::ordering::apply_symmetric_permutation;
use phi_spmv::sparse::{Csr, MatrixStats};
use phi_spmv::tuner::space::{enumerate, SpaceConfig};
use phi_spmv::tuner::{Ordering, Prepared, Trialer, Tuner, TunerConfig, TuningCache};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

/// Tunes `a` under `space`, re-measures the pick with the baseline
/// protocol, and returns (decision, GFlop/s, milliseconds spent in the
/// tune itself — the search cost only, not the re-measurement).
fn tune_and_measure(
    name: &str,
    a: &Csr,
    x: &[f64],
    space: SpaceConfig,
    bencher: &Bencher,
) -> (phi_spmv::tuner::TunedConfig, f64, f64) {
    let config = TunerConfig { space, ..TunerConfig::default() };
    let mut tuner = Tuner::new(config, TuningCache::in_memory());
    let t0 = std::time::Instant::now();
    let decision = tuner.tune(name, a).expect("tuning failed");
    let tune_ms = t0.elapsed().as_secs_f64() * 1e3;
    let prepared = Prepared::new(a, decision.candidate());
    let gflops = bencher.run("tuned", || prepared.spmv(x)).gflops(2.0 * a.nnz() as f64);
    (decision, gflops, tune_ms)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64).clamp(1e-4, 1.0);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let bencher = Bencher::quick();
    let suite = paper_suite();

    // Quad mesh, scattered circuit, power-law web, FEM, 2D stencil — plus
    // a banded matrix scrambled by a random symmetric permutation, the
    // §4.4 case the ordering axis exists for.
    let mut cases: Vec<(String, Csr)> = [0usize, 2, 7, 11, 19]
        .iter()
        .map(|&idx| {
            let entry = &suite[idx];
            let mut a = entry.generate_scaled(scale);
            randomize_values(&mut a, entry.id as u64);
            (entry.name.to_string(), a)
        })
        .collect();
    {
        let n = ((40_000.0 * scale) as usize).max(500);
        let a = banded_runs(&BandedSpec {
            n,
            mean_row: 10.0,
            run: 4,
            locality: 0.01,
            seed: 31,
        });
        let mut rng = Rng::new(32);
        let mut shuffle: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = rng.usize_below(i + 1);
            shuffle.swap(i, j);
        }
        cases.push(("scrambled-band".to_string(), apply_symmetric_permutation(&a, &shuffle)));
    }

    println!(
        "{:<16} {:>6} {:>9} | {:>12} {:>12} {:>12} {:>12} {:>12} | {:<28} {:>6}",
        "matrix", "cands", "tune_ms", "default", "tuned", "tuned_nat", "best_cand", "worst_cand",
        "decision", "ok"
    );

    let mut matrices: Vec<Json> = Vec::new();
    for (name, a) in &cases {
        let x = random_vector(a.ncols, 61);
        let flops = 2.0 * a.nnz() as f64;

        // Baseline: the configuration every experiment in the paper
        // defaults to (CSR, dynamic,64, all threads).
        let baseline = bencher.run("default", || {
            phi_spmv::kernels::spmv_parallel(a, &x, threads, Policy::Dynamic(64))
        });

        // The tuner's decision over the full space (its own short trials,
        // in-memory cache), with the search cost timed on its own...
        let (decision, tuned_gflops, tune_ms) =
            tune_and_measure(name, a, &x, SpaceConfig::default(), &bencher);
        // ...and the same search with the ordering axis pinned to natural
        // order — what the tuner would have picked before RCM was a
        // search dimension.
        let natural_space =
            SpaceConfig { orderings: vec![Ordering::Natural], ..SpaceConfig::default() };
        let (natural_decision, natural_gflops, _) =
            tune_and_measure(name, a, &x, natural_space, &bencher);

        // Sweep the whole candidate space once more to locate the
        // best/worst envelope the search chose from. The envelope must
        // fully time every candidate, so the early-termination budget is
        // disabled (an infinite margin also preserves the given order).
        let stats = MatrixStats::compute(name, a);
        let space = enumerate(a, &stats, &SpaceConfig::default());
        let results =
            Trialer::default().with_margin(f64::INFINITY).run_all(a, &space.candidates);
        let best = results.iter().map(|r| r.gflops).fold(0.0f64, f64::max);
        let worst = results.iter().map(|r| r.gflops).fold(f64::INFINITY, f64::min);

        // Acceptance: the tuned config must never be slower than the worst
        // candidate in its own space (10% timing-noise allowance).
        let ok = tuned_gflops >= worst * 0.9;
        if !ok {
            eprintln!(
                "WARN {name}: tuned {tuned_gflops:.3} GFlop/s below worst candidate {worst:.3}"
            );
        }
        println!(
            "{:<16} {:>6} {:>9.1} | {:>9.3} GF {:>9.3} GF {:>9.3} GF {:>9.3} GF {:>9.3} GF | {:<28} {:>6}",
            name,
            space.candidates.len(),
            tune_ms,
            baseline.gflops(flops),
            tuned_gflops,
            natural_gflops,
            best,
            worst,
            format!(
                "{} {} {} t{}",
                decision.format, decision.ordering, decision.policy, decision.threads
            ),
            ok
        );
        matrices.push(
            Json::obj()
                .set("name", name.as_str())
                .set("nrows", a.nrows)
                .set("nnz", a.nnz())
                .set("candidates", space.candidates.len())
                .set("tune_ms", tune_ms)
                .set("default_gflops", baseline.gflops(flops))
                .set("tuned_gflops", tuned_gflops)
                .set("tuned_natural_gflops", natural_gflops)
                .set("best_candidate_gflops", best)
                .set("worst_candidate_gflops", worst)
                .set("decision", decision.to_json())
                .set("decision_natural", natural_decision.to_json())
                .set("ok", ok),
        );
    }

    let report = Json::obj()
        .set("bench", "autotune")
        .set("threads", threads)
        .set("scale", scale)
        .set("matrices", matrices);
    let path = "BENCH_autotune.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_autotune.json");
    println!("\nwrote {path}");
}
