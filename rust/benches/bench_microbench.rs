//! Micro-benchmarks (paper Fig. 1 / Fig. 2) plus roofline calibration.
//!
//! Measured: host read/write bandwidth with the same kernels the paper
//! uses (char sum, vectorized f64 sum, fill), across thread counts, and
//! the full [`MachineRoofline`] calibration pass (streaming-read peak,
//! pointer-chase latency, multiply-add ceiling). Modeled: the calibrated
//! KNC curves at the paper's sweep points.
//!
//! `cargo bench --bench bench_microbench [-- --scale 1.0]` writes
//! `BENCH_microbench.json` with the calibrated peak read GB/s,
//! random-access latency in ns, and the per-ISA flop-ceiling table.

use phi_spmv::kernels::micro::{
    host_fill, host_sum_bytes, host_sum_f64, model_read, model_write, ReadBench, WriteBench,
};
use phi_spmv::kernels::simd::IsaLevel;
use phi_spmv::telemetry::MachineRoofline;
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 1.0f64);
    let bencher = Bencher::new(3, 10);
    let max_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    println!("== measured: host memory bandwidth ==");
    let bytes: Vec<u8> = vec![1u8; 64 << 20];
    let doubles: Vec<f64> = vec![1.5f64; 8 << 20];
    let mut buf = vec![0.0f64; 8 << 20];
    for t in [1usize, 2, 4, max_threads] {
        if t > max_threads {
            continue;
        }
        let m = bencher.run(&format!("char sum, {t} threads"), || host_sum_bytes(&bytes, t));
        println!("{}  {:.2} GB/s", m.line(), m.gbps(bytes.len() as f64));
        let m = bencher.run(&format!("f64 vector sum, {t} threads"), || host_sum_f64(&doubles, t));
        println!("{}  {:.2} GB/s", m.line(), m.gbps((doubles.len() * 8) as f64));
        let m = bencher.run(&format!("fill, {t} threads"), || host_fill(&mut buf, 2.0, t));
        println!("{}  {:.2} GB/s", m.line(), m.gbps((buf.len() * 8) as f64));
    }

    println!("\n== modeled: KNC Fig. 1 read benches (61 cores) ==");
    println!("{:<22} {:>8} {:>8} {:>8} {:>8}", "bench", "1t", "2t", "3t", "4t");
    for (name, b) in [
        ("a: char sum", ReadBench::SumChar),
        ("b: int sum", ReadBench::SumInt),
        ("c: vector sum", ReadBench::SumVector),
        ("d: vector+prefetch", ReadBench::SumVectorPrefetch),
    ] {
        let g: Vec<f64> = (1..=4).map(|t| model_read(b, 61, t).gbps).collect();
        println!("{name:<22} {:>8.1} {:>8.1} {:>8.1} {:>8.1}", g[0], g[1], g[2], g[3]);
    }

    println!("\n== modeled: KNC Fig. 2 write benches (61 cores) ==");
    for (name, b) in [
        ("a: store", WriteBench::Store),
        ("b: store+NR", WriteBench::StoreNoRead),
        ("c: store+NRNGO", WriteBench::StoreNrNgo),
    ] {
        let g: Vec<f64> = (1..=4).map(|t| model_write(b, 61, t).gbps).collect();
        println!("{name:<22} {:>8.1} {:>8.1} {:>8.1} {:>8.1}", g[0], g[1], g[2], g[3]);
    }

    // The same calibration pass the serving examples install at startup
    // (telemetry::MachineRoofline), exported so CI can gate "achieved
    // never exceeds peak" against a figure measured on the same runner.
    println!("\n== measured: machine roofline calibration (scale {scale}) ==");
    let roof = MachineRoofline::calibrate_scaled(scale);
    let detected = IsaLevel::detect();
    println!("peak streaming read   {:>10.2} GB/s", roof.peak_read_gbps);
    println!("random-access latency {:>10.1} ns", roof.random_latency_ns);
    println!("roofline knee         {:>10.3} flop/B", roof.knee_flops_per_byte());
    println!("flop ceiling ({}: measured; others projected)", detected.name());
    let mut ceilings = Json::obj();
    for isa in [IsaLevel::Portable, IsaLevel::Avx2, IsaLevel::Avx512] {
        let mark = if isa == detected { " *" } else { "" };
        println!("  {:<10} {:>10.2} GFlop/s{mark}", isa.name(), roof.flop_ceiling(isa));
        ceilings = ceilings.set(isa.name(), roof.flop_ceiling(isa));
    }

    let report = Json::obj()
        .set("bench", "microbench")
        .set("threads", max_threads)
        .set("scale", scale)
        .set("peak_read_gbps", roof.peak_read_gbps)
        .set("random_latency_ns", roof.random_latency_ns)
        .set("knee_flops_per_byte", roof.knee_flops_per_byte())
        .set("detected_isa", detected.name())
        .set("flop_ceiling_gflops", ceilings);
    let path = "BENCH_microbench.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_microbench.json");
    println!("\nwrote {path}");
}
