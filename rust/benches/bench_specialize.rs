//! Specialization payoff benchmarks: the const-generic registry
//! micro-kernels (`kernels::specialize`) against the runtime-parameter
//! generic loops, per format family × shape × workload.
//!
//! Measured on two generator-suite classes (the 2D stencil and the
//! pwtk-like FEM instance — the block-dense cases the BCSR and SELL
//! variants exist for): the same matrix, pool, schedule and thread
//! count, with only the inner loop swapped, so every ratio isolates
//! exactly what baking the shape into the instruction stream buys.
//!
//! `cargo bench --bench bench_specialize [-- --scale 0.05]` writes
//! `BENCH_specialize.json` with per-case GFlop/s for both payloads, the
//! speedup ratio, and a `payoff` summary naming the two CI-gated cases
//! (BCSR 4×4 SpMV and SELL-8 SpMV; the gate applies on vector hosts —
//! check the report's `isa` field).

use phi_spmv::kernels::{ExecCtx, IsaLevel, SpmvOp, Workload};
use phi_spmv::kernels::specialize;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::tuner::{exec::prepare, prepare_spec, Format};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn run_case(op: &dyn SpmvOp, x: &[f64], y: &mut [f64], k: usize, ctx: &ExecCtx<'_>) {
    if k > 1 {
        op.spmm_into(x, y, k, ctx)
    } else {
        op.spmv_into(x, y, ctx)
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let suite = paper_suite();
    let isa = IsaLevel::detect();
    let ctx = ExecCtx::pooled(threads, Policy::Dynamic(64));

    // (format, workload) cases the registry advertises. CSR runs both
    // workloads (unroll for SpMV, k-block for SpMM); the block/chunk
    // families are SpMV-kind only, matching the tuner's coverage rule.
    let cases: Vec<(Format, Workload)> = vec![
        (Format::Csr, Workload::Spmv),
        (Format::Csr, Workload::Spmm { k: 8 }),
        (Format::Bcsr { r: 2, c: 2 }, Workload::Spmv),
        (Format::Bcsr { r: 4, c: 4 }, Workload::Spmv),
        (Format::Bcsr { r: 8, c: 8 }, Workload::Spmv),
        (Format::Sell { c: 4, sigma: 256 }, Workload::Spmv),
        (Format::Sell { c: 8, sigma: 256 }, Workload::Spmv),
        (Format::Sell { c: 16, sigma: 256 }, Workload::Spmv),
    ];

    println!(
        "== specialization payoff: {} registry variants, {isa}, {threads} threads, scale {scale} ==",
        specialize::registry().len()
    );
    println!(
        "{:<16} {:<12} {:<8} {:>10} {:>12} {:>8}  variant",
        "matrix", "format", "workload", "spec GF", "generic GF", "speedup"
    );

    // 2D stencil and the pwtk-like FEM case (the paper's SpMM peak).
    let mut matrices: Vec<Json> = Vec::new();
    // The CI gate reads these two: BCSR 4×4 SpMV and SELL-8 SpMV on the
    // stencil (dense diagonal blocks, uniform rows — the shapes the
    // registry was built for).
    let mut gate_bcsr4x4 = 0.0f64;
    let mut gate_sell8 = 0.0f64;
    for (which, idx) in [("stencil", 19usize), ("fem", 11usize)] {
        let entry = &suite[idx];
        let mut a = entry.generate_scaled(scale);
        randomize_values(&mut a, entry.id as u64);
        let mut rows: Vec<Json> = Vec::new();
        for (format, workload) in &cases {
            let (format, workload) = (*format, *workload);
            let k = workload.k();
            let Some(spec_op) = prepare_spec(&a, format, k) else {
                // Registry does not cover this shape at this ISA (e.g. a
                // non-x86 build): report the hole instead of skipping
                // silently.
                println!("{:<16} {:<12} {:<8} {:>10}", entry.name, format, workload, "uncovered");
                rows.push(
                    Json::obj()
                        .set("format", format.to_string())
                        .set("workload", workload.to_string())
                        .set("covered", false),
                );
                continue;
            };
            let generic_op = prepare(&a, format);
            let variant = spec_op.variant_name().unwrap_or("?");
            let x = random_vector(a.ncols * k, 4);
            let mut y = vec![0.0f64; a.nrows * k];
            let flops = workload.flops(a.nnz());
            let spec_gf = bencher
                .run("spec", || run_case(spec_op.as_ref(), &x, &mut y, k, &ctx))
                .gflops(flops);
            let generic_gf = bencher
                .run("generic", || run_case(generic_op.as_ref(), &x, &mut y, k, &ctx))
                .gflops(flops);
            let speedup = spec_gf / generic_gf.max(1e-12);
            if which == "stencil" && workload == Workload::Spmv {
                if format == (Format::Bcsr { r: 4, c: 4 }) {
                    gate_bcsr4x4 = speedup;
                }
                if let Format::Sell { c: 8, .. } = format {
                    gate_sell8 = speedup;
                }
            }
            println!(
                "{:<16} {:<12} {:<8} {:>10.3} {:>12.3} {:>7.2}x  {variant}",
                entry.name, format, workload, spec_gf, generic_gf, speedup
            );
            rows.push(
                Json::obj()
                    .set("format", format.to_string())
                    .set("workload", workload.to_string())
                    .set("covered", true)
                    .set("variant", variant)
                    .set("spec_gflops", spec_gf)
                    .set("generic_gflops", generic_gf)
                    .set("speedup", speedup),
            );
        }
        matrices.push(
            Json::obj()
                .set("name", entry.name)
                .set("class", which)
                .set("nrows", a.nrows)
                .set("nnz", a.nnz())
                .set("cases", rows),
        );
    }

    let report = Json::obj()
        .set("bench", "specialize")
        .set("isa", isa.name())
        .set("threads", threads)
        .set("scale", scale)
        .set("registry_variants", specialize::registry().len())
        .set(
            "payoff",
            Json::obj()
                .set("bcsr4x4_spmv_speedup", gate_bcsr4x4)
                .set("sell8_spmv_speedup", gate_sell8),
        )
        .set("matrices", matrices);
    let path = "BENCH_specialize.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_specialize.json");
    println!("\nwrote {path}");
}
