//! Inner-kernel ISA benchmarks: the vectorized (`std::arch`) kernels
//! against the portable scalar loops, per format × workload.
//!
//! Measured: every storage format under SpMV and fused SpMM (k = 16) on
//! three generator-suite classes, once with the detected
//! [`IsaLevel`](phi_spmv::kernels::IsaLevel) and once forced portable via
//! [`ExecCtx::with_isa`] — the same payload, pool and schedule, so the
//! ratio isolates exactly what the explicit vector kernels buy. On a
//! portable-only host both runs take the scalar path and every ratio is
//! ~1.0 (the report's `isa` field says which case it measured).
//!
//! `cargo bench --bench bench_kernels [-- --scale 0.05]` writes
//! `BENCH_kernels.json` with GFlop/s per (matrix × format × workload)
//! for both ISA levels and their speedup ratio.

use phi_spmv::kernels::{ExecCtx, IsaLevel, Workload};
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::tuner::{exec::prepare, Format};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let suite = paper_suite();
    let isa = IsaLevel::detect();
    let vec_ctx = ExecCtx::pooled(threads, Policy::Dynamic(64));
    let scalar_ctx = ExecCtx::pooled(threads, Policy::Dynamic(64)).with_isa(IsaLevel::Portable);

    // SELL-C snapped to the vector lane count, exactly as the tuner's
    // default space does (8 on portable hosts — the paper's width).
    let lanes = isa.lanes();
    let sell_c = if lanes > 1 { lanes } else { 8 };
    let formats = [
        Format::Csr,
        Format::Ell,
        Format::Hyb { width: 8 },
        Format::Sell { c: sell_c, sigma: 256 },
        Format::Bcsr { r: 4, c: 2 },
    ];
    let workloads = [Workload::Spmv, Workload::Spmm { k: 16 }];

    println!("== inner kernels: {isa} vs portable, {threads} threads, scale {scale} ==");
    println!(
        "{:<16} {:<10} {:<8} {:>10} {:>12} {:>8}",
        "matrix", "format", "workload", "isa GF", "portable GF", "speedup"
    );
    // 2D stencil, the paper's SpMM peak instance (pwtk), web graph.
    let mut matrices: Vec<Json> = Vec::new();
    for idx in [19usize, 11, 7] {
        let entry = &suite[idx];
        let mut a = entry.generate_scaled(scale);
        randomize_values(&mut a, entry.id as u64);
        let mut by_format = Json::obj();
        for format in formats {
            let op = prepare(&a, format);
            let mut by_workload = Json::obj();
            for workload in workloads {
                let k = workload.k();
                let x = random_vector(a.ncols * k, 4);
                let mut y = vec![0.0f64; a.nrows * k];
                let flops = workload.flops(a.nnz());
                let vectorized = bencher
                    .run("isa", || {
                        if k > 1 {
                            op.spmm_into(&x, &mut y, k, &vec_ctx)
                        } else {
                            op.spmv_into(&x, &mut y, &vec_ctx)
                        }
                    })
                    .gflops(flops);
                let portable = bencher
                    .run("portable", || {
                        if k > 1 {
                            op.spmm_into(&x, &mut y, k, &scalar_ctx)
                        } else {
                            op.spmv_into(&x, &mut y, &scalar_ctx)
                        }
                    })
                    .gflops(flops);
                let speedup = vectorized / portable.max(1e-12);
                println!(
                    "{:<16} {:<10} {:<8} {:>10.3} {:>12.3} {:>7.2}x",
                    entry.name, format, workload, vectorized, portable, speedup
                );
                by_workload = by_workload.set(
                    &workload.to_string(),
                    Json::obj()
                        .set("isa_gflops", vectorized)
                        .set("portable_gflops", portable)
                        .set("speedup", speedup),
                );
            }
            by_format = by_format.set(&format.to_string(), by_workload);
        }
        matrices.push(
            Json::obj()
                .set("name", entry.name)
                .set("nrows", a.nrows)
                .set("nnz", a.nnz())
                .set("formats", by_format),
        );
    }

    let report = Json::obj()
        .set("bench", "kernels")
        .set("isa", isa.name())
        .set("lanes", lanes)
        .set("threads", threads)
        .set("scale", scale)
        .set("sell_c", sell_c)
        .set("matrices", matrices);
    let path = "BENCH_kernels.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_kernels.json");
    println!("\nwrote {path}");
}
