//! Register-blocking benchmarks (paper §4.5, Table 2).
//!
//! Measured: BCSR conversion cost + native blocked SpMV vs plain CSR on
//! host hardware, for all seven paper block shapes. Modeled: Table 2's
//! relative-performance row on KNC.
//!
//! `cargo bench --bench bench_blocking [-- --scale 0.05]`

use phi_spmv::arch::PhiMachine;
use phi_spmv::kernels::blocked_model::bcsr_profile;
use phi_spmv::kernels::native::bcsr_spmv_parallel;
use phi_spmv::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use phi_spmv::kernels::spmv_parallel;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::bcsr::PAPER_BLOCK_CONFIGS;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::sparse::Bcsr;
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let machine = PhiMachine::se10p();
    let suite = paper_suite();

    // cant (dense 3-blocks, blocking-friendliest) and scircuit (hostile).
    for idx in [5usize, 2] {
        let e = &suite[idx];
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let x = random_vector(a.ncols, 6);
        let flops = 2.0 * a.nnz() as f64;

        let base = bencher.run(&format!("csr/{}", e.name), || {
            spmv_parallel(&a, &x, threads, Policy::Dynamic(64))
        });
        let base_gfs = base.gflops(flops);
        println!("== {} ({} nnz): CSR {:.3} GFlop/s ==", e.name, a.nnz(), base_gfs);
        println!(
            "{:>6} {:>10} {:>12} {:>12} {:>12} {:>10}",
            "block", "density", "conv_ms", "native GF/s", "native rel", "model rel"
        );

        let an = SpmvAnalysis::compute(&a, 61);
        let model_base = machine
            .best_config(&spmv_profile(&a, SpmvVariant::O3, &an), &[60, 61])
            .2
            .gflops();
        for (r, c) in PAPER_BLOCK_CONFIGS {
            let conv = bencher.run(&format!("bcsr{r}x{c}/{}", e.name), || Bcsr::from_csr(&a, r, c));
            let b = Bcsr::from_csr(&a, r, c);
            let nat = bencher.run(&format!("bspmv{r}x{c}/{}", e.name), || {
                bcsr_spmv_parallel(&b, &x, threads, Policy::Dynamic(16))
            });
            let nat_gfs = nat.gflops(flops);
            let model_rel = machine
                .best_config(&bcsr_profile(&a, &b, 61), &[60, 61])
                .2
                .gflops()
                / model_base;
            println!(
                "{:>6} {:>10.3} {:>12.2} {:>12.3} {:>12.2} {:>10.2}",
                format!("{r}x{c}"),
                b.block_density(a.nnz()),
                conv.mean_s * 1e3,
                nat_gfs,
                nat_gfs / base_gfs,
                model_rel
            );
        }
    }
}
