//! SpMM benchmarks (paper §5, Fig. 9).
//!
//! Measured: native SpMM across k ∈ {1, 4, 8, 16, 32} showing the
//! flop:byte-driven throughput growth (the paper's core §5 argument), and
//! a policy sweep at k=16. Modeled: the KNC Fig. 9 variant triple.
//!
//! `cargo bench --bench bench_spmm [-- --scale 0.05]`

use phi_spmv::arch::PhiMachine;
use phi_spmv::kernels::spmm_model::{spmm_profile, SpmmAnalysis, SpmmVariant};
use phi_spmv::kernels::{spmm_parallel, spmv_parallel};
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let suite = paper_suite();

    // pwtk — the paper's SpMM peak instance.
    let e = &suite[11];
    let mut a = e.generate_scaled(scale);
    randomize_values(&mut a, 12);

    println!("== measured: native SpMM on {} ({} nnz), {threads} threads ==", e.name, a.nnz());
    let x1 = random_vector(a.ncols, 4);
    let m1 = bencher.run("spmv (k=1 baseline)", || {
        spmv_parallel(&a, &x1, threads, Policy::Dynamic(64))
    });
    println!("{}  {:.3} GFlop/s", m1.line(), m1.gflops(2.0 * a.nnz() as f64));
    for k in [4usize, 8, 16, 32] {
        let x = random_vector(a.ncols * k, 4);
        let m = bencher.run(&format!("spmm k={k}"), || {
            spmm_parallel(&a, &x, k, threads, Policy::Dynamic(64))
        });
        println!("{}  {:.3} GFlop/s", m.line(), m.gflops(2.0 * a.nnz() as f64 * k as f64));
    }

    println!("\n== modeled: KNC Fig. 9 (k=16) ==");
    let machine = PhiMachine::se10p();
    println!(
        "{:>2} {:<16} {:>9} {:>9} {:>9}",
        "#", "name", "generic", "manual", "nrngo"
    );
    for e in &suite {
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let an = SpmmAnalysis::compute(&a, 61, 16);
        let g: Vec<f64> = [SpmmVariant::Generic, SpmmVariant::Manual, SpmmVariant::Nrngo]
            .into_iter()
            .map(|v| machine.best_config(&spmm_profile(&a, v, &an), &[60, 61]).2.gflops())
            .collect();
        println!("{:>2} {:<16} {:>9.1} {:>9.1} {:>9.1}", e.id, e.name, g[0], g[1], g[2]);
    }
}
