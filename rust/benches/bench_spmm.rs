//! SpMM benchmarks (paper §5, Fig. 9): fused kernels vs the k-pass
//! fallback, per format × batch width, plus the workload-aware tuner.
//!
//! Measured: every format's fused `spmm_into` against the gather/SpMV/
//! scatter fallback (`spmm_via_spmv`) across k ∈ {1, 4, 16, 32} on three
//! generator-suite classes — the payoff measurement for the fused SpMM
//! kernels (the matrix is read once per k vectors instead of k times).
//! Also records the tuner's SpMV and SpMM decisions for one matrix to
//! show the workload dimension selecting differently. Modeled: the KNC
//! Fig. 9 variant triple.
//!
//! `cargo bench --bench bench_spmm [-- --scale 0.05]` writes
//! `BENCH_spmm.json` with GFlop/s per (matrix × format × k), the
//! fused:fallback ratio, and both tuner decisions.

use phi_spmv::arch::PhiMachine;
use phi_spmv::kernels::spmm_model::{spmm_profile, SpmmAnalysis, SpmmVariant};
use phi_spmv::kernels::{spmm_via_spmv, ExecCtx, SpmvOp, Workload};
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::tuner::{exec::prepare, Format, Tuner, TunerConfig, TuningCache};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let suite = paper_suite();
    let ctx = ExecCtx::pooled(threads, Policy::Dynamic(64));

    let formats = [
        Format::Csr,
        Format::Ell,
        Format::Hyb { width: 8 },
        Format::Sell { c: 8, sigma: 256 },
        Format::Bcsr { r: 4, c: 2 },
    ];
    let ks = [1usize, 4, 16, 32];

    // Quad mesh, the paper's SpMM peak instance (pwtk), 2D stencil.
    println!("== measured: fused SpMM vs k-pass fallback, {threads} threads ==");
    println!(
        "{:<16} {:<10} {:>4} {:>12} {:>14} {:>8}",
        "matrix", "format", "k", "fused GF", "fallback GF", "ratio"
    );
    let mut matrices: Vec<Json> = Vec::new();
    for idx in [0usize, 11, 19] {
        let entry = &suite[idx];
        let mut a = entry.generate_scaled(scale);
        randomize_values(&mut a, entry.id as u64);
        let mut by_format = Json::obj();
        for format in formats {
            let op = prepare(&a, format);
            let mut by_k = Json::obj();
            for k in ks {
                let x = random_vector(a.ncols * k, 4);
                let mut y = vec![0.0f64; a.nrows * k];
                let flops = Workload::Spmm { k }.flops(a.nnz());
                let fused = bencher
                    .run("fused", || op.spmm_into(&x, &mut y, k, &ctx))
                    .gflops(flops);
                let fallback = bencher
                    .run("fallback", || spmm_via_spmv(op.as_ref(), &x, &mut y, k, &ctx))
                    .gflops(flops);
                let ratio = fused / fallback.max(1e-12);
                println!(
                    "{:<16} {:<10} {:>4} {:>12.3} {:>14.3} {:>7.2}x",
                    entry.name, format, k, fused, fallback, ratio
                );
                by_k = by_k.set(
                    &format!("k{k}"),
                    Json::obj()
                        .set("fused_gflops", fused)
                        .set("fallback_gflops", fallback)
                        .set("ratio", ratio),
                );
            }
            by_format = by_format.set(&format.to_string(), by_k);
        }
        matrices.push(
            Json::obj()
                .set("name", entry.name)
                .set("nrows", a.nrows)
                .set("nnz", a.nnz())
                .set("formats", by_format),
        );
    }

    // The workload dimension in the tuner: the same matrix, two searches,
    // two (potentially different) decisions under distinct cache keys.
    let entry = &suite[11];
    let mut a = entry.generate_scaled(scale);
    randomize_values(&mut a, entry.id as u64);
    let mut tuner = Tuner::new(TunerConfig::default(), TuningCache::in_memory());
    let spmv = tuner.tune(entry.name, &a).expect("spmv tuning failed");
    let spmm = tuner
        .tune_workload(entry.name, &a, Workload::Spmm { k: 16 })
        .expect("spmm tuning failed");
    let distinct = spmv.candidate() != spmm.candidate();
    println!("\n== tuner on {}: per-workload decisions ==", entry.name);
    println!("spmv:   {spmv}");
    println!("spmm16: {spmm}");
    println!("distinct candidates: {distinct}");

    println!("\n== modeled: KNC Fig. 9 (k=16) ==");
    let machine = PhiMachine::se10p();
    println!("{:>2} {:<16} {:>9} {:>9} {:>9}", "#", "name", "generic", "manual", "nrngo");
    for e in &suite {
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let an = SpmmAnalysis::compute(&a, 61, 16);
        let g: Vec<f64> = [SpmmVariant::Generic, SpmmVariant::Manual, SpmmVariant::Nrngo]
            .into_iter()
            .map(|v| machine.best_config(&spmm_profile(&a, v, &an), &[60, 61]).2.gflops())
            .collect();
        println!("{:>2} {:<16} {:>9.1} {:>9.1} {:>9.1}", e.id, e.name, g[0], g[1], g[2]);
    }

    let report = Json::obj()
        .set("bench", "spmm")
        .set("threads", threads)
        .set("scale", scale)
        .set("ks", ks.to_vec())
        .set("matrices", matrices)
        .set(
            "tuner",
            Json::obj()
                .set("matrix", entry.name)
                .set("spmv", spmv.to_json())
                .set("spmm16", spmm.to_json())
                .set("distinct", distinct),
        );
    let path = "BENCH_spmm.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_spmm.json");
    println!("\nwrote {path}");
}
