//! Partitioning ablation — the paper's §7 future work, quantified:
//! contiguous balanced partitioning vs round-robin `dynamic,64` chunking,
//! measured by (a) total x-cachelines transferred across 61 simulated
//! caches (the Vector Access driver) and (b) modeled KNC SpMV GFlop/s
//! with the partitioned traffic.
//!
//! `cargo bench --bench bench_partition [-- --scale 0.05]`

use phi_spmv::arch::PhiMachine;
use phi_spmv::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use phi_spmv::sched::{Policy, StaticAssignment};
use phi_spmv::sparse::partition::{assignment_vector_lines, Partition};
use phi_spmv::sparse::gen::{paper_suite, randomize_values};
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let machine = PhiMachine::se10p();
    let suite = paper_suite();

    println!(
        "{:>2} {:<16} {:>12} {:>12} {:>8} {:>10} {:>10} {:>9}",
        "#", "name", "rr_lines", "part_lines", "saved", "rr GF/s", "part GF/s", "imbal"
    );
    for e in &suite {
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let rr = StaticAssignment::build(Policy::Dynamic(64), a.nrows, 61);
        let part = Partition::contiguous_balanced(&a, 61);
        let lines_rr = assignment_vector_lines(&a, &rr);
        let lines_part = assignment_vector_lines(&a, &part.to_assignment());

        // Model the effect: swap the traffic term in the -O3 profile.
        let an = SpmvAnalysis::compute(&a, 61);
        let w_rr = spmv_profile(&a, SpmvVariant::O3, &an);
        let mut w_part = w_rr;
        let ratio = lines_part as f64 / an.traffic.lines_infinite.max(1) as f64;
        w_part.random_read_lines = (w_rr.random_read_lines * ratio).max(lines_part as f64 * 0.5);
        w_part.imbalance = part.imbalance(&a).max(1.0);
        let g_rr = machine.best_config(&w_rr, &[60, 61]).2.gflops();
        let g_part = machine.best_config(&w_part, &[60, 61]).2.gflops();

        println!(
            "{:>2} {:<16} {:>12} {:>12} {:>7.0}% {:>10.2} {:>10.2} {:>9.2}",
            e.id,
            e.name,
            lines_rr,
            lines_part,
            100.0 * (1.0 - lines_part as f64 / lines_rr.max(1) as f64),
            g_rr,
            g_part,
            part.imbalance(&a)
        );
    }
}
