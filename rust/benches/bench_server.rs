//! Serving-path benchmark: pooled execution vs spawn-per-call, at batch 1
//! and at steady state — the payoff measurement for the persistent
//! [`WorkerPool`](phi_spmv::sched::WorkerPool) refactor, and the start of
//! the server's perf trajectory (`BENCH_server.json`).
//!
//! Two phases per backend:
//! * `batch1` — sequential request/response round trips with batching
//!   disabled: every batch pays the kernel launch, so the spawn-per-call
//!   backend pays thread creation on each request.
//! * `steady` — a flood of concurrent requests with batching enabled: the
//!   batcher fuses up to 16 requests per SpMM and the kernel launch cost
//!   amortizes; what remains is exactly the per-launch overhead the pool
//!   removes.
//!
//! `cargo bench --bench bench_server [-- --requests 200]` writes
//! `BENCH_server.json` with p50/p99 latency and kernel GFlop/s per
//! (backend × phase).

use std::sync::Arc;
use std::time::Duration;

use phi_spmv::coordinator::server::{percentile, PathSpec, ServerConfig, SpmvServer};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::Csr;
use phi_spmv::telemetry::{Telemetry, TelemetrySnapshot};
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

struct PhaseResult {
    p50_ms: f64,
    p99_ms: f64,
    gflops: f64,
    mean_batch: f64,
}

impl PhaseResult {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("gflops", self.gflops)
            .set("mean_batch", self.mean_batch)
    }
}

/// Drives one server instance through `requests` requests; `flood` submits
/// them all up front (steady state), otherwise one at a time (batch 1).
fn run_phase(a: &Arc<Csr>, cfg: ServerConfig, requests: usize, flood: bool) -> PhaseResult {
    let server = SpmvServer::start(a.clone(), cfg);
    let client = server.client();
    let mut latencies = Vec::with_capacity(requests);
    if flood {
        let rxs: Vec<_> = (0..requests)
            .map(|s| client.submit(random_vector(a.ncols, 1000 + s as u64)).unwrap())
            .collect();
        for rx in rxs {
            latencies.push(rx.recv().unwrap().latency);
        }
    } else {
        for s in 0..requests {
            let resp = client.call(random_vector(a.ncols, 2000 + s as u64)).unwrap();
            latencies.push(resp.latency);
        }
    }
    latencies.sort();
    let stats = server.shutdown();
    PhaseResult {
        p50_ms: percentile(&latencies, 0.50).as_secs_f64() * 1e3,
        p99_ms: percentile(&latencies, 0.99).as_secs_f64() * 1e3,
        gflops: stats.flops / stats.compute_s.max(1e-9) / 1e9,
        mean_batch: stats.mean_batch(),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let requests = args.get("requests", 200usize);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut a = powerlaw(&PowerLawSpec {
        n: 20_000,
        nnz: 240_000,
        row_alpha: 1.7,
        col_alpha: 1.5,
        max_row: 64,
        seed: 7,
    });
    randomize_values(&mut a, 8);
    let a = Arc::new(a);
    println!(
        "server bench: {} rows, {} nnz, {threads} threads, {requests} requests/phase",
        a.nrows,
        a.nnz()
    );
    println!(
        "{:<16} {:<8} {:>10} {:>10} {:>10} {:>11}",
        "backend", "phase", "p50 ms", "p99 ms", "GFlop/s", "mean batch"
    );

    // One telemetry instance across all four phases: the bench's snapshot
    // artifact records the whole run's latency histograms and counters
    // next to BENCH_server.json.
    let telemetry = Telemetry::new();
    let mut modes = Json::obj();
    let mut results = Vec::new();
    for (label, pooled) in [("pooled", true), ("spawn_per_call", false)] {
        let spmv = PathSpec { threads, ..PathSpec::default() };
        let batch1 = run_phase(
            &a,
            ServerConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
                spmv: spmv.clone(),
                pooled,
                telemetry: telemetry.clone(),
                ..ServerConfig::default()
            },
            requests,
            false,
        );
        let steady = run_phase(
            &a,
            ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(2),
                spmv,
                pooled,
                telemetry: telemetry.clone(),
                ..ServerConfig::default()
            },
            requests,
            true,
        );
        for (phase, r) in [("batch1", &batch1), ("steady", &steady)] {
            println!(
                "{label:<16} {phase:<8} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
                r.p50_ms, r.p99_ms, r.gflops, r.mean_batch
            );
        }
        modes = modes.set(
            label,
            Json::obj().set("batch1", batch1.to_json()).set("steady", steady.to_json()),
        );
        results.push((label, batch1, steady));
    }

    let (pooled_b1, pooled_st) = (&results[0].1, &results[0].2);
    let (spawn_b1, spawn_st) = (&results[1].1, &results[1].2);
    println!(
        "pooled vs spawn: batch1 p50 {:.2}x, steady p50 {:.2}x, steady GFlop/s {:.2}x",
        spawn_b1.p50_ms / pooled_b1.p50_ms.max(1e-9),
        spawn_st.p50_ms / pooled_st.p50_ms.max(1e-9),
        pooled_st.gflops / spawn_st.gflops.max(1e-9),
    );

    let report = Json::obj()
        .set("bench", "server")
        .set(
            "matrix",
            Json::obj().set("nrows", a.nrows).set("ncols", a.ncols).set("nnz", a.nnz()),
        )
        .set("threads", threads)
        .set("requests_per_phase", requests)
        .set("modes", modes);
    let path = "BENCH_server.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_server.json");
    println!("wrote {path}");

    let snap = TelemetrySnapshot::capture(&telemetry);
    TelemetrySnapshot::parse(&snap.to_pretty()).expect("snapshot must round-trip");
    snap.write("TELEMETRY_server.json").expect("writing TELEMETRY_server.json");
    println!("wrote TELEMETRY_server.json");
}
