//! Fleet benchmark: aggregate throughput and tail latency as the number
//! of registered matrices grows under a *fixed* memory budget — the
//! price of multi-tenancy (`BENCH_fleet.json`).
//!
//! At low entry counts every payload stays warm and requests go straight
//! to a running engine; past the budget the fleet starts evicting, and
//! the traffic pays re-materialization (payload re-preparation) on cold
//! hits. The JSON reports, per entry count: aggregate GFlop/s over all
//! paths, client p50/p99 latency, and the eviction/re-materialization
//! counts that explain them.
//!
//! Two further scenarios ride along:
//!
//! * **heavy** — the intake front door under fire: hundreds of client
//!   threads across mixed-size tenants, run twice (admission control on
//!   with tight in-flight budgets, then off), reporting p50/p99/p999
//!   client latency and the shed counts. The comparison is the point:
//!   shedding trades a slice of the offered load for a bounded tail.
//! * **shard** — one large matrix served unsharded, then row-sharded
//!   across 2 and 4 independently tuned engines, with a deep in-flight
//!   pipeline; reports wall-clock aggregate GFlop/s per shard count and
//!   the best sharded-over-unsharded speedup (the CI smoke gate).
//!
//! `cargo bench --bench bench_fleet [-- --requests 400 --scale 1.0 --clients 200]`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use phi_spmv::coordinator::server::percentile;
use phi_spmv::fleet::{
    Admission, BatchConfig, Fleet, FleetConfig, Intake, RetuneConfig, ShardConfig, TenantBudget,
};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::{random_vector, randomize_values, Rng};
use phi_spmv::sparse::Csr;
use phi_spmv::tuner::{Tuner, TunerConfig, TuningCache};
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn matrices(count: usize, scale: f64) -> Vec<(String, Arc<Csr>)> {
    (0..count)
        .map(|i| {
            let n = ((6_000.0 + 800.0 * i as f64) * scale).max(200.0) as usize;
            let spec = PowerLawSpec {
                n,
                nnz: 10 * n,
                row_alpha: 1.7,
                col_alpha: 1.5,
                max_row: 48,
                seed: 60 + i as u64,
            };
            let mut a = powerlaw(&spec);
            randomize_values(&mut a, 70 + i as u64);
            (format!("m{i}"), Arc::new(a))
        })
        .collect()
}

struct Run {
    gflops: f64,
    p50_ms: f64,
    p99_ms: f64,
    evictions: usize,
    rematerializations: usize,
    warm_bytes: usize,
}

fn run_fleet(entry_count: usize, scale: f64, requests: usize, budget: usize) -> Run {
    let mats = matrices(entry_count, scale);
    let fleet = Fleet::new(
        FleetConfig {
            memory_budget_bytes: budget,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            // Pure serving measurement: no background thread, no width
            // walk — the single-server bench already covers those axes.
            retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
            batch: BatchConfig { min_samples: usize::MAX, ..BatchConfig::default() },
            ..FleetConfig::default()
        },
        Tuner::quick(),
    );
    for (id, a) in &mats {
        fleet.register(id, a.clone()).expect("register");
    }
    // Round-robin-with-skew traffic in bursts of 8, so batches fuse.
    let mut rng = Rng::new(99);
    let mut latencies: Vec<Duration> = Vec::with_capacity(requests);
    let mut pending = Vec::new();
    for r in 0..requests {
        let idx = if rng.bool(0.6) { r % 2 } else { rng.usize_below(mats.len()) };
        let (id, a) = &mats[idx];
        let x = random_vector(a.ncols, 1_000 + r as u64);
        pending.push(fleet.submit(id, x).expect("submit"));
        if pending.len() >= 8 {
            for rx in pending.drain(..) {
                latencies.push(rx.recv().expect("response").latency);
            }
        }
    }
    for rx in pending.drain(..) {
        latencies.push(rx.recv().expect("response").latency);
    }
    latencies.sort();
    let warm_bytes = fleet.storage_bytes();
    let stats = fleet.shutdown();
    Run {
        gflops: stats.gflops(),
        p50_ms: percentile(&latencies, 0.50).as_secs_f64() * 1e3,
        p99_ms: percentile(&latencies, 0.99).as_secs_f64() * 1e3,
        evictions: stats.evictions,
        rematerializations: stats.rematerializations,
        warm_bytes,
    }
}

fn model_tuner() -> Tuner {
    Tuner::new(TunerConfig::model_only(), TuningCache::in_memory())
}

fn quiet_config() -> FleetConfig {
    FleetConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        batch: BatchConfig { min_samples: usize::MAX, ..BatchConfig::default() },
        ..FleetConfig::default()
    }
}

struct HeavyRun {
    admitted: u64,
    shed: u64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    wall_s: f64,
}

impl HeavyRun {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("admitted", self.admitted)
            .set("shed", self.shed)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("p999_ms", self.p999_ms)
            .set("wall_s", self.wall_s)
    }
}

/// Hundreds of client threads hammering mixed-size tenants through the
/// intake, with admission control either biting (tight per-tenant
/// in-flight budgets) or disabled (unlimited budgets).
fn run_heavy(scale: f64, clients: usize, shed_on: bool) -> HeavyRun {
    let sizes = [1_500.0, 4_000.0, 8_000.0];
    let mats: Vec<(String, Arc<Csr>)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let n = (s * scale).max(200.0) as usize;
            let spec = PowerLawSpec {
                n,
                nnz: 10 * n,
                row_alpha: 1.7,
                col_alpha: 1.5,
                max_row: 48,
                seed: 200 + i as u64,
            };
            let mut a = powerlaw(&spec);
            randomize_values(&mut a, 210 + i as u64);
            (format!("t{i}"), Arc::new(a))
        })
        .collect();
    let fleet = Fleet::new(quiet_config(), model_tuner());
    for (id, a) in &mats {
        fleet.register(id, a.clone()).expect("register");
    }
    let budget = if shed_on {
        TenantBudget { max_inflight: 16, ..TenantBudget::unlimited() }
    } else {
        TenantBudget::unlimited()
    };
    let intake = Arc::new(Intake::new(fleet, budget));
    let latencies = Arc::new(Mutex::new(Vec::<Duration>::new()));
    let shed = Arc::new(AtomicU64::new(0));
    let rounds = 4usize;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let intake = intake.clone();
            let mats = mats.clone();
            let latencies = latencies.clone();
            let shed = shed.clone();
            std::thread::spawn(move || {
                let mut local = Vec::with_capacity(rounds);
                for round in 0..rounds {
                    let (id, a) = &mats[(c + round) % mats.len()];
                    let x = random_vector(a.ncols, (c * rounds + round) as u64);
                    let start = Instant::now();
                    match intake.submit(id, x).expect("submit") {
                        Admission::Admitted(ticket) => {
                            ticket.recv().expect("admitted requests are answered");
                            local.push(start.elapsed());
                        }
                        Admission::Shed { .. } => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                latencies.lock().unwrap().extend(local);
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let mut latencies = std::mem::take(&mut *latencies.lock().unwrap());
    latencies.sort();
    HeavyRun {
        admitted: latencies.len() as u64,
        shed: shed.load(Ordering::Relaxed),
        p50_ms: percentile(&latencies, 0.50).as_secs_f64() * 1e3,
        p99_ms: percentile(&latencies, 0.99).as_secs_f64() * 1e3,
        p999_ms: percentile(&latencies, 0.999).as_secs_f64() * 1e3,
        wall_s,
    }
}

/// One large matrix, a deep in-flight pipeline, `shards` engines.
/// Returns (actual shard count, wall-clock aggregate GFlop/s).
fn run_shard(scale: f64, shards: usize) -> (usize, f64) {
    let n = (6_000.0 * scale).max(400.0) as usize;
    let spec = PowerLawSpec {
        n,
        nnz: 12 * n,
        row_alpha: 1.7,
        col_alpha: 1.5,
        max_row: 64,
        seed: 300,
    };
    let mut a = powerlaw(&spec);
    randomize_values(&mut a, 301);
    let a = Arc::new(a);
    let shard = if shards > 1 {
        ShardConfig { threshold_nnz: 0, shards }
    } else {
        ShardConfig::default()
    };
    let fleet = Fleet::new(FleetConfig { shard, ..quiet_config() }, model_tuner());
    fleet.register("big", a.clone()).expect("register");
    let actual = fleet.shard_count("big").unwrap_or(1);
    let requests = 256usize;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|r| fleet.submit("big", random_vector(a.ncols, 400 + r as u64)).expect("submit"))
        .collect();
    for s in pending {
        s.recv().expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    fleet.shutdown();
    (actual, requests as f64 * 2.0 * a.nnz() as f64 / wall.max(1e-12) / 1e9)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let requests = args.get("requests", 400usize);
    let scale = args.get("scale", 1.0f64);
    let counts = [2usize, 4, 8];

    // Fix the budget to what the 2-entry population needs, so growing
    // the entry count squeezes the same budget harder.
    let base: usize = matrices(2, scale).iter().map(|(_, a)| a.storage_bytes()).sum();
    let budget = base + base / 2;
    println!("fleet bench: budget {budget} B, {requests} requests per entry count");
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "entries", "GFlop/s", "p50 ms", "p99 ms", "warm B", "evict", "remat"
    );

    let mut by_count = Json::obj();
    for &count in &counts {
        let t0 = Instant::now();
        let run = run_fleet(count, scale, requests, budget);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{count:<8} {:>10.3} {:>10.3} {:>10.3} {:>10} {:>8} {:>8}   ({wall:.1}s)",
            run.gflops, run.p50_ms, run.p99_ms, run.warm_bytes, run.evictions,
            run.rematerializations
        );
        by_count = by_count.set(
            &count.to_string(),
            Json::obj()
                .set("gflops", run.gflops)
                .set("p50_ms", run.p50_ms)
                .set("p99_ms", run.p99_ms)
                .set("warm_bytes", run.warm_bytes)
                .set("evictions", run.evictions)
                .set("rematerializations", run.rematerializations),
        );
    }

    // Heavy concurrency through the intake: admission control on vs off.
    let clients = args.get("clients", 200usize);
    println!("\nheavy: {clients} client threads × 4 rounds, mixed tenant sizes");
    println!(
        "{:<10} {:>10} {:>8} {:>10} {:>10} {:>10} {:>8}",
        "shedding", "admitted", "shed", "p50 ms", "p99 ms", "p999 ms", "wall s"
    );
    let mut heavy = Json::obj().set("clients", clients);
    for (label, on) in [("on", true), ("off", false)] {
        let run = run_heavy(scale, clients, on);
        println!(
            "{label:<10} {:>10} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>8.2}",
            run.admitted, run.shed, run.p50_ms, run.p99_ms, run.p999_ms, run.wall_s
        );
        heavy = heavy.set(&format!("shed_{label}"), run.to_json());
    }

    // Scale-out: the same large matrix unsharded vs row-sharded.
    println!("\nshard: one large matrix, 256 requests in flight");
    println!("{:<8} {:>8} {:>10}", "asked", "engines", "GFlop/s");
    let mut shard_json = Json::obj();
    let mut unsharded_gf = 0.0f64;
    let mut best_speedup = 0.0f64;
    for s in [1usize, 2, 4] {
        let (actual, gf) = run_shard(scale, s);
        println!("{s:<8} {actual:>8} {gf:>10.3}");
        if s == 1 {
            unsharded_gf = gf;
        } else if unsharded_gf > 0.0 {
            best_speedup = best_speedup.max(gf / unsharded_gf);
        }
        shard_json = shard_json
            .set(&s.to_string(), Json::obj().set("engines", actual).set("gflops", gf));
    }
    shard_json = shard_json.set("best_speedup", best_speedup);
    println!("best sharded speedup over unsharded: {best_speedup:.2}×");

    let report = Json::obj()
        .set("bench", "fleet")
        .set("budget_bytes", budget)
        .set("requests_per_count", requests)
        .set("scale", scale)
        .set("by_entry_count", by_count)
        .set("heavy", heavy)
        .set("shard", shard_json);
    let path = "BENCH_fleet.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_fleet.json");
    println!("wrote {path}");
}
