//! Fleet benchmark: aggregate throughput and tail latency as the number
//! of registered matrices grows under a *fixed* memory budget — the
//! price of multi-tenancy (`BENCH_fleet.json`).
//!
//! At low entry counts every payload stays warm and requests go straight
//! to a running engine; past the budget the fleet starts evicting, and
//! the traffic pays re-materialization (payload re-preparation) on cold
//! hits. The JSON reports, per entry count: aggregate GFlop/s over all
//! paths, client p50/p99 latency, and the eviction/re-materialization
//! counts that explain them.
//!
//! `cargo bench --bench bench_fleet [-- --requests 400 --scale 1.0]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use phi_spmv::coordinator::server::percentile;
use phi_spmv::fleet::{BatchConfig, Fleet, FleetConfig, RetuneConfig};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::{random_vector, randomize_values, Rng};
use phi_spmv::sparse::Csr;
use phi_spmv::tuner::Tuner;
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn matrices(count: usize, scale: f64) -> Vec<(String, Arc<Csr>)> {
    (0..count)
        .map(|i| {
            let n = ((6_000.0 + 800.0 * i as f64) * scale).max(200.0) as usize;
            let spec = PowerLawSpec {
                n,
                nnz: 10 * n,
                row_alpha: 1.7,
                col_alpha: 1.5,
                max_row: 48,
                seed: 60 + i as u64,
            };
            let mut a = powerlaw(&spec);
            randomize_values(&mut a, 70 + i as u64);
            (format!("m{i}"), Arc::new(a))
        })
        .collect()
}

struct Run {
    gflops: f64,
    p50_ms: f64,
    p99_ms: f64,
    evictions: usize,
    rematerializations: usize,
    warm_bytes: usize,
}

fn run_fleet(entry_count: usize, scale: f64, requests: usize, budget: usize) -> Run {
    let mats = matrices(entry_count, scale);
    let fleet = Fleet::new(
        FleetConfig {
            memory_budget_bytes: budget,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            // Pure serving measurement: no background thread, no width
            // walk — the single-server bench already covers those axes.
            retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
            batch: BatchConfig { min_samples: usize::MAX, ..BatchConfig::default() },
            ..FleetConfig::default()
        },
        Tuner::quick(),
    );
    for (id, a) in &mats {
        fleet.register(id, a.clone()).expect("register");
    }
    // Round-robin-with-skew traffic in bursts of 8, so batches fuse.
    let mut rng = Rng::new(99);
    let mut latencies: Vec<Duration> = Vec::with_capacity(requests);
    let mut pending = Vec::new();
    for r in 0..requests {
        let idx = if rng.bool(0.6) { r % 2 } else { rng.usize_below(mats.len()) };
        let (id, a) = &mats[idx];
        let x = random_vector(a.ncols, 1_000 + r as u64);
        pending.push(fleet.submit(id, x).expect("submit"));
        if pending.len() >= 8 {
            for rx in pending.drain(..) {
                latencies.push(rx.recv().expect("response").latency);
            }
        }
    }
    for rx in pending.drain(..) {
        latencies.push(rx.recv().expect("response").latency);
    }
    latencies.sort();
    let warm_bytes = fleet.storage_bytes();
    let stats = fleet.shutdown();
    Run {
        gflops: stats.gflops(),
        p50_ms: percentile(&latencies, 0.50).as_secs_f64() * 1e3,
        p99_ms: percentile(&latencies, 0.99).as_secs_f64() * 1e3,
        evictions: stats.evictions,
        rematerializations: stats.rematerializations,
        warm_bytes,
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let requests = args.get("requests", 400usize);
    let scale = args.get("scale", 1.0f64);
    let counts = [2usize, 4, 8];

    // Fix the budget to what the 2-entry population needs, so growing
    // the entry count squeezes the same budget harder.
    let base: usize = matrices(2, scale).iter().map(|(_, a)| a.storage_bytes()).sum();
    let budget = base + base / 2;
    println!("fleet bench: budget {budget} B, {requests} requests per entry count");
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "entries", "GFlop/s", "p50 ms", "p99 ms", "warm B", "evict", "remat"
    );

    let mut by_count = Json::obj();
    for &count in &counts {
        let t0 = Instant::now();
        let run = run_fleet(count, scale, requests, budget);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{count:<8} {:>10.3} {:>10.3} {:>10.3} {:>10} {:>8} {:>8}   ({wall:.1}s)",
            run.gflops, run.p50_ms, run.p99_ms, run.warm_bytes, run.evictions,
            run.rematerializations
        );
        by_count = by_count.set(
            &count.to_string(),
            Json::obj()
                .set("gflops", run.gflops)
                .set("p50_ms", run.p50_ms)
                .set("p99_ms", run.p99_ms)
                .set("warm_bytes", run.warm_bytes)
                .set("evictions", run.evictions)
                .set("rematerializations", run.rematerializations),
        );
    }

    let report = Json::obj()
        .set("bench", "fleet")
        .set("budget_bytes", budget)
        .set("requests_per_count", requests)
        .set("scale", scale)
        .set("by_entry_count", by_count);
    let path = "BENCH_fleet.json";
    std::fs::write(path, report.to_pretty()).expect("writing BENCH_fleet.json");
    println!("wrote {path}");
}
