//! SpMV benchmarks (paper Fig. 4 / Fig. 5 / Fig. 6).
//!
//! Two parts:
//! * **measured** — the native Rust kernel on host hardware, across
//!   representative suite matrices, thread counts and scheduling policies
//!   (the paper's §4.1 sweep);
//! * **modeled** — the calibrated KNC model regenerating the paper's
//!   Fig. 4 rows (-O1 vs -O3 GFlop/s per matrix).
//!
//! `cargo bench --bench bench_spmv [-- --scale 0.05]`

use phi_spmv::analysis::app_bytes_spmv;
use phi_spmv::arch::PhiMachine;
use phi_spmv::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use phi_spmv::kernels::spmv_parallel;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let suite = paper_suite();

    println!("== measured: native SpMV, {threads} threads, scale {scale} ==");
    // Representative picks: stencil / FEM / web / scattered / dense-rows.
    for idx in [19usize, 11, 7, 3, 17] {
        let e = &suite[idx];
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let x = random_vector(a.ncols, 3);
        let flops = 2.0 * a.nnz() as f64;
        for policy in [Policy::StaticBlock, Policy::Dynamic(32), Policy::Dynamic(64)] {
            let m = bencher.run(&format!("spmv/{}/{policy}", e.name), || {
                spmv_parallel(&a, &x, threads, policy)
            });
            println!(
                "{}  {:.3} GFlop/s  app {:.2} GB/s",
                m.line(),
                m.gflops(flops),
                m.gbps(app_bytes_spmv(&a))
            );
        }
    }

    // §Perf ablation: portable vs vectorized inner loop, allocation
    // on/off the hot path, chunk-size sweep.
    println!("\n== §Perf ablation (pwtk class, serial + threaded) ==");
    {
        use phi_spmv::kernels::{ExecCtx, IsaLevel, SpmvOp};
        let e = &suite[11];
        let mut a = e.generate_scaled(scale.max(0.1));
        phi_spmv::sparse::gen::randomize_values(&mut a, 12);
        let x = random_vector(a.ncols, 9);
        let flops = 2.0 * a.nnz() as f64;
        let mut y = vec![0.0; a.nrows];
        let portable_ctx = ExecCtx::serial().with_isa(IsaLevel::Portable);
        let m0 = bencher.run("portable serial (before)", || {
            a.spmv_into(&x, &mut y, &portable_ctx)
        });
        println!("{}  {:.3} GFlop/s", m0.line(), m0.gflops(flops));
        let detected_ctx = ExecCtx::serial();
        let m1 = bencher.run(&format!("{} serial (after)", detected_ctx.isa), || {
            a.spmv_into(&x, &mut y, &detected_ctx)
        });
        println!("{}  {:.3} GFlop/s  ({:+.1}%)", m1.line(), m1.gflops(flops),
            100.0 * (m0.mean_s / m1.mean_s - 1.0));
        let m2 = bencher.run("alloc per call (before)", || {
            spmv_parallel(&a, &x, threads, Policy::Dynamic(64))
        });
        println!("{}  {:.3} GFlop/s", m2.line(), m2.gflops(flops));
        let m3 = bencher.run("into-buffer (after)", || {
            phi_spmv::kernels::spmv_parallel_into(&a, &x, &mut y, threads, Policy::Dynamic(64))
        });
        println!("{}  {:.3} GFlop/s  ({:+.1}%)", m3.line(), m3.gflops(flops),
            100.0 * (m2.mean_s / m3.mean_s - 1.0));
        for chunk in [16usize, 64, 256, 1024] {
            let m = bencher.run(&format!("chunk {chunk}"), || {
                phi_spmv::kernels::spmv_parallel_into(&a, &x, &mut y, threads, Policy::Dynamic(chunk))
            });
            println!("{}  {:.3} GFlop/s", m.line(), m.gflops(flops));
        }
    }

    println!("\n== modeled: KNC Fig. 4 (-O1 vs -O3), scale {scale} ==");
    let machine = PhiMachine::se10p();
    println!("{:>2} {:<16} {:>10} {:>10} {:>8}", "#", "name", "o1 GF/s", "o3 GF/s", "x");
    for e in &suite {
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let an = SpmvAnalysis::compute(&a, 61);
        let g1 = machine
            .best_config(&spmv_profile(&a, SpmvVariant::O1, &an), &[60, 61])
            .2
            .gflops();
        let g3 = machine
            .best_config(&spmv_profile(&a, SpmvVariant::O3, &an), &[60, 61])
            .2
            .gflops();
        println!("{:>2} {:<16} {:>10.2} {:>10.2} {:>8.2}", e.id, e.name, g1, g3, g3 / g1);
    }
}
