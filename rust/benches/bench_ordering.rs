//! RCM ordering benchmarks (paper §4.4, Fig. 8).
//!
//! Measured: RCM computation time and the native-SpMV effect of the
//! reordering on host hardware. Modeled: the KNC Fig. 8 deltas.
//!
//! `cargo bench --bench bench_ordering [-- --scale 0.05]`

use phi_spmv::analysis::vector_traffic;
use phi_spmv::arch::PhiMachine;
use phi_spmv::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use phi_spmv::kernels::spmv_parallel;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::sparse::ordering::{apply_symmetric_permutation, rcm};
use phi_spmv::sparse::stats::{matrix_bandwidth, ucld};
use phi_spmv::util::bench::Bencher;
use phi_spmv::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = args.get("scale", 0.05f64);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let bencher = Bencher::quick();
    let machine = PhiMachine::se10p();
    let suite = paper_suite();

    println!(
        "{:>2} {:<16} {:>9} {:>9} {:>8} {:>8} {:>9} {:>9} {:>10}",
        "#", "name", "bw_pre", "bw_post", "ucld_pre", "ucld_post", "gfs_pre", "gfs_post", "rcm_ms"
    );
    // F1, cant, pre2, webbase: the paper's biggest winner, an already-local
    // FEM, a circuit, and a web graph (RCM-hostile).
    for idx in [16usize, 5, 10, 7] {
        let e = &suite[idx];
        let mut a = e.generate_scaled(scale);
        randomize_values(&mut a, e.id as u64);
        let m = bencher.run(&format!("rcm/{}", e.name), || rcm(&a));
        let perm = rcm(&a);
        let b = apply_symmetric_permutation(&a, &perm);

        let gfs = |mat: &phi_spmv::sparse::Csr| {
            let an = SpmvAnalysis::compute(mat, 61);
            machine
                .best_config(&spmv_profile(mat, SpmvVariant::O3, &an), &[60, 61])
                .2
                .gflops()
        };
        println!(
            "{:>2} {:<16} {:>9} {:>9} {:>8.3} {:>8.3} {:>9.2} {:>9.2} {:>10.2}",
            e.id,
            e.name,
            matrix_bandwidth(&a),
            matrix_bandwidth(&b),
            ucld(&a),
            ucld(&b),
            gfs(&a),
            gfs(&b),
            m.mean_s * 1e3
        );

        // Host-measured effect of reordering on the native kernel.
        let x = random_vector(a.ncols, 5);
        let flops = 2.0 * a.nnz() as f64;
        let ma = bencher
            .run(&format!("native/{}/orig", e.name), || spmv_parallel(&a, &x, threads, Policy::Dynamic(64)));
        let xb = random_vector(b.ncols, 5);
        let mb = bencher
            .run(&format!("native/{}/rcm", e.name), || spmv_parallel(&b, &xb, threads, Policy::Dynamic(64)));
        println!(
            "    native: {:.3} → {:.3} GFlop/s; vector access {:.2} → {:.2}",
            ma.gflops(flops),
            mb.gflops(flops),
            vector_traffic(&a, 61, 64, 8).vector_access(),
            vector_traffic(&b, 61, 64, 8).vector_access()
        );
    }
}
