//! Concurrency stress harness for the admission-controlled intake:
//! hundreds of client threads against a small fleet under an
//! eviction-forcing memory budget. The invariants under fire:
//!
//! * **zero wrong answers** — every admitted request's response matches
//!   its own oracle, no matter how batches fuse or entries churn;
//! * **zero lost non-shed requests** — an admitted ticket always
//!   redeems to a response;
//! * **shed requests are always explicitly rejected** — a shed is an
//!   `Admission::Shed { reason }` verdict returned immediately, never a
//!   hang or a silent drop;
//! * **exact accounting** — per-tenant scoreboards, the intake
//!   counters, and the bounded journal's drop-oldest bookkeeping all
//!   reconcile to the thread-side tallies.
//!
//! Client count: env `PHI_STRESS_CLIENTS` (default 200).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use phi_spmv::fleet::{
    Admission, Fleet, FleetConfig, Intake, RetuneConfig, ShedReason, TenantBudget,
};
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::Csr;
use phi_spmv::tuner::{Tuner, TunerConfig, TuningCache};

const TENANTS: usize = 3;
const ROUNDS: usize = 5;

fn client_count() -> usize {
    std::env::var("PHI_STRESS_CLIENTS").ok().and_then(|v| v.parse().ok()).unwrap_or(200)
}

fn matrix(seed: u64, n: usize) -> Arc<Csr> {
    let mut a = stencil_2d(n, n);
    randomize_values(&mut a, seed);
    Arc::new(a)
}

fn quiet_fleet(memory_budget_bytes: usize) -> Fleet {
    let tuner = Tuner::new(TunerConfig::model_only(), TuningCache::in_memory());
    let config = FleetConfig {
        memory_budget_bytes,
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        ..FleetConfig::default()
    };
    Fleet::new(config, tuner)
}

#[test]
fn hundreds_of_clients_zero_wrong_answers_exact_accounting() {
    let matrices: Vec<Arc<Csr>> = (0..TENANTS).map(|i| matrix(100 + i as u64, 16)).collect();
    // Budget for roughly two of the three entries: the round-robin
    // traffic below forces evict/re-materialize churn *while* requests
    // are in flight.
    let budget_bytes = 2 * matrices[0].storage_bytes() + matrices[0].storage_bytes() / 2;
    let fleet = quiet_fleet(budget_bytes);
    // Subscribe before any events so seen + missed reconciles to the
    // full published history.
    let telemetry = fleet.telemetry();
    let mut audit = telemetry.journal.subscribe();
    for (i, a) in matrices.iter().enumerate() {
        fleet.register(&format!("t{i}"), a.clone()).unwrap();
    }
    // Tight in-flight caps so admission control actually bites under
    // this thread count; tenant t2 additionally gets a byte cap.
    let intake = Arc::new(Intake::new(fleet, TenantBudget {
        max_inflight: 8,
        ..TenantBudget::unlimited()
    }));
    intake.set_budget("t2", TenantBudget {
        max_inflight: 8,
        max_inflight_bytes: matrices[2].ncols * 8 * 4,
        ..TenantBudget::unlimited()
    });

    let clients = client_count();
    let ok = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let wrong = Arc::new(AtomicU64::new(0));
    let lost = Arc::new(AtomicU64::new(0));
    let submit_errors = Arc::new(AtomicU64::new(0));

    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let intake = intake.clone();
            let matrices = matrices.clone();
            let (ok, shed, wrong, lost, submit_errors) = (
                ok.clone(),
                shed.clone(),
                wrong.clone(),
                lost.clone(),
                submit_errors.clone(),
            );
            std::thread::spawn(move || {
                for round in 0..ROUNDS {
                    let tid = (c + round) % TENANTS;
                    let a = &matrices[tid];
                    let x = random_vector(a.ncols, (c * ROUNDS + round) as u64);
                    match intake.submit(&format!("t{tid}"), x.clone()) {
                        Ok(Admission::Admitted(ticket)) => match ticket.recv() {
                            Ok(resp) => {
                                let want = a.spmv(&x);
                                let bad = resp.y.iter().zip(&want).any(|(u, v)| {
                                    (u - v).abs() >= 1e-9 * (1.0 + v.abs())
                                });
                                if bad || resp.y.len() != want.len() {
                                    wrong.fetch_add(1, Ordering::Relaxed);
                                } else {
                                    ok.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                lost.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Ok(Admission::Shed { .. }) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            submit_errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client threads must not panic");
    }

    let (ok, shed) = (ok.load(Ordering::Relaxed), shed.load(Ordering::Relaxed));
    assert_eq!(wrong.load(Ordering::Relaxed), 0, "zero wrong answers");
    assert_eq!(lost.load(Ordering::Relaxed), 0, "admitted requests must all be answered");
    assert_eq!(submit_errors.load(Ordering::Relaxed), 0, "registered tenants never error");
    assert_eq!(ok + shed, (clients * ROUNDS) as u64, "every request has exactly one fate");

    // The scoreboards reconcile to the thread-side tallies…
    let report = intake.report();
    assert_eq!(report.iter().map(|r| r.admitted).sum::<u64>(), ok);
    assert_eq!(report.iter().map(|r| r.shed).sum::<u64>(), shed);
    // …and so do the counters.
    assert_eq!(
        telemetry.metrics.counter(phi_spmv::telemetry::names::INTAKE_ADMITTED).get(),
        ok
    );
    assert_eq!(telemetry.metrics.counter(phi_spmv::telemetry::names::INTAKE_SHED).get(), shed);
    // Journal drop accounting is exact even when the bounded buffer
    // overflowed: every published event is either still buffered,
    // counted as dropped, and the cumulative per-kind counts include
    // one `shed` per shed verdict.
    let journal = &telemetry.journal;
    assert_eq!(journal.dropped(), journal.published() - journal.len() as u64);
    let (seen, missed) = audit.poll(journal);
    assert_eq!(seen.len() as u64 + missed, journal.published());
    let shed_events =
        journal.counts().iter().find(|(k, _)| *k == "shed").map(|(_, n)| *n).unwrap_or(0);
    assert_eq!(shed_events, shed, "one journal `shed` event per shed verdict");

    let stats = Arc::try_unwrap(intake).ok().expect("all clients joined").shutdown();
    assert!(stats.evictions >= 1, "the budget must have forced eviction under load");
    assert!(stats.rematerializations >= 1, "evicted entries must have come back");
    assert_eq!(stats.served() as u64, ok, "engines served exactly the admitted requests");
}

#[test]
fn sheds_are_explicit_and_immediate() {
    let fleet = quiet_fleet(0);
    let a = matrix(7, 12);
    fleet.register("t", a.clone()).unwrap();
    let intake = Intake::new(fleet, TenantBudget::unlimited());

    // In-flight cap 0: every request is shed — each verdict is an
    // explicit rejection carrying the tripped axis.
    intake.set_budget("t", TenantBudget { max_inflight: 0, ..TenantBudget::unlimited() });
    for _ in 0..50 {
        match intake.submit("t", vec![1.0; a.ncols]).unwrap() {
            Admission::Shed { reason } => assert_eq!(reason, ShedReason::Inflight),
            Admission::Admitted(_) => panic!("a zero-inflight budget must shed everything"),
        }
    }
    // into_ticket surfaces the shed as an error that names the axis.
    let err = intake.submit("t", vec![1.0; a.ncols]).unwrap().into_ticket().unwrap_err();
    assert!(err.to_string().contains("inflight"), "unexpected message: {err}");

    // Rate limiting: a fresh-budget bucket grants the burst, then
    // binds. At ~zero qps the bucket never refills, so after the two
    // burst tokens every further request is shed with the qps reason.
    let fleet2 = quiet_fleet(0);
    fleet2.register("r", a.clone()).unwrap();
    let intake2 = Intake::new(fleet2, TenantBudget::unlimited());
    intake2.set_budget("r", TenantBudget { max_qps: 1e-9, burst: 2, ..TenantBudget::unlimited() });
    let mut tickets = Vec::new();
    for _ in 0..2 {
        match intake2.submit("r", vec![1.0; a.ncols]).unwrap() {
            Admission::Admitted(t) => tickets.push(t),
            Admission::Shed { reason } => panic!("burst tokens must admit, shed as {reason:?}"),
        }
    }
    for _ in 0..10 {
        match intake2.submit("r", vec![1.0; a.ncols]).unwrap() {
            Admission::Shed { reason } => assert_eq!(reason, ShedReason::RateLimit),
            Admission::Admitted(_) => panic!("a dry bucket must rate-limit"),
        }
    }
    for t in tickets {
        t.recv().expect("admitted burst requests must be answered");
    }
    intake2.shutdown();
    intake.shutdown();
}

#[test]
fn slo_pressure_walks_width_down_and_shedding_recovery_walks_it_up() {
    let fleet = quiet_fleet(0);
    let a = matrix(9, 16);
    fleet.register("t", a.clone()).unwrap();
    let intake = Intake::new(fleet, TenantBudget::unlimited());
    assert_eq!(intake.fleet().current_max_batch("t"), Some(16));

    // An unmeetable SLO: every judged window violates, and each
    // maintenance pass walks the width one rung down the ladder.
    intake.set_budget("t", TenantBudget {
        p99_target: Duration::from_nanos(1),
        ..TenantBudget::unlimited()
    });
    for i in 0..4 {
        let x = random_vector(a.ncols, 60 + i);
        intake.call("t", x).unwrap();
    }
    intake.maintain();
    assert_eq!(intake.fleet().current_max_batch("t"), Some(8), "p99 pressure: 16 → 8");
    for i in 0..4 {
        let x = random_vector(a.ncols, 70 + i);
        intake.call("t", x).unwrap();
    }
    intake.maintain();
    assert_eq!(intake.fleet().current_max_batch("t"), Some(4), "p99 pressure: 8 → 4");

    let report = intake.report();
    assert_eq!(report.len(), 1);
    assert!(report[0].violations >= 2);
    assert!(!report[0].compliant);
    assert!(report[0].last_p99.unwrap() > Duration::from_nanos(1));
    let t = intake.fleet().telemetry();
    assert!(t.metrics.counter(phi_spmv::telemetry::names::SLO_VIOLATIONS).get() >= 2);
    assert!(t.journal.counts().iter().any(|(k, n)| *k == "slo_violation" && *n >= 2));
    assert!(t.journal.counts().iter().any(|(k, n)| *k == "slo_width_changed" && *n >= 2));

    // Now the tenant is compliant (loose target) but shedding: the next
    // judged window nudges the width back up for throughput.
    intake.set_budget("t", TenantBudget { max_inflight: 0, ..TenantBudget::unlimited() });
    for _ in 0..3 {
        assert!(matches!(intake.submit("t", vec![0.0; a.ncols]).unwrap(), Admission::Shed { .. }));
    }
    intake.set_budget("t", TenantBudget::unlimited());
    for i in 0..4 {
        let x = random_vector(a.ncols, 80 + i);
        intake.call("t", x).unwrap();
    }
    intake.maintain();
    assert_eq!(
        intake.fleet().current_max_batch("t"),
        Some(8),
        "compliant + shedding: width back up one rung"
    );
    intake.shutdown();
}
