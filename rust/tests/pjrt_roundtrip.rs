//! End-to-end AOT integration: JAX/Pallas-lowered HLO artifacts executed
//! through the PJRT CPU client must agree with the serial CSR oracle.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use phi_spmv::runtime::Runtime;
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::{Coo, Csr};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn assert_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len());
    for (i, (u, v)) in a.iter().zip(b).enumerate() {
        assert!((u - v).abs() <= tol * (1.0 + v.abs()), "idx {i}: {u} vs {v}");
    }
}

#[test]
fn spmv_pjrt_matches_oracle_stencil() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut a = stencil_2d(50, 60); // 3000 rows → r4096 bucket
    randomize_values(&mut a, 42);
    let exe = rt.spmv(&a).unwrap();
    assert_eq!(exe.meta.rows, 4096);
    let x = random_vector(a.ncols, 7);
    let got = rt.run_spmv(&exe, &x).unwrap();
    assert_close(&got, &a.spmv(&x), 1e-12);
}

#[test]
fn spmv_pjrt_larger_bucket() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut a = stencil_2d(100, 100); // 10k rows → r16384
    randomize_values(&mut a, 43);
    let exe = rt.spmv(&a).unwrap();
    assert_eq!(exe.meta.rows, 16384);
    let x = random_vector(a.ncols, 8);
    let got = rt.run_spmv(&exe, &x).unwrap();
    assert_close(&got, &a.spmv(&x), 1e-12);
}

#[test]
fn spmv_pjrt_wide_rows_pick_w16() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Rows with up to 12 nonzeros need the w16 bucket.
    let mut coo = Coo::new(2000, 2000);
    for i in 0..2000usize {
        for d in 0..(1 + i % 12) {
            coo.push(i, (i + d * 7) % 2000, 1.0 + d as f64);
        }
    }
    let a = coo.to_csr();
    let exe = rt.spmv(&a).unwrap();
    assert_eq!(exe.meta.width, 16);
    let x = random_vector(2000, 9);
    let got = rt.run_spmv(&exe, &x).unwrap();
    assert_close(&got, &a.spmv(&x), 1e-12);
}

#[test]
fn spmm_pjrt_matches_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut a = stencil_2d(40, 50);
    randomize_values(&mut a, 44);
    let k = 16;
    let exe = rt.spmm(&a, k).unwrap();
    let x = random_vector(a.ncols * k, 10);
    let got = rt.run_spmm(&exe, &x).unwrap();
    assert_close(&got, &a.spmm(&x, k), 1e-12);
}

#[test]
fn power_step_pjrt_semantics() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = stencil_2d(60, 60); // SPD Laplacian, 3600 rows → r4096 power bucket
    let exe = rt.power_step(&a).unwrap();
    let x = random_vector(a.nrows, 11);
    let (xn, norm, rayleigh) = rt.run_power_step(&exe, &x).unwrap();
    let y = a.spmv(&x);
    let want_norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let want_ray: f64 = x.iter().zip(&y).map(|(u, v)| u * v).sum();
    assert!((norm - want_norm).abs() < 1e-9 * want_norm);
    assert!((rayleigh - want_ray).abs() < 1e-9 * want_ray.abs());
    let want_xn: Vec<f64> = y.iter().map(|v| v / want_norm).collect();
    assert_close(&xn, &want_xn, 1e-10);
}

#[test]
fn power_iteration_converges_to_dominant_eigenvalue() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // 2D Laplacian eigenvalues: λ(i,j) = 4 − 2cos(iπ/(n+1)) − 2cos(jπ/(n+1));
    // the dominant one is 4 + 4cos(π/(n+1)). A 20×20 grid keeps the spectral
    // gap large enough for power iteration to converge in a few hundred
    // steps (on 60² the top eigenvalues are nearly degenerate).
    let a = stencil_2d(20, 20);
    let exe = rt.power_step(&a).unwrap();
    let mut x = random_vector(a.nrows, 12);
    let norm0 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    x.iter_mut().for_each(|v| *v /= norm0);
    let mut lambda = 0.0;
    for _ in 0..500 {
        let (xn, _, rayleigh) = rt.run_power_step(&exe, &x).unwrap();
        x = xn;
        lambda = rayleigh; // x was unit-norm → rayleigh = xᵀAx
    }
    let nx = 20.0f64;
    let expected = 4.0 + 4.0 * (std::f64::consts::PI / (nx + 1.0)).cos();
    assert!(
        (lambda - expected).abs() < 0.005,
        "λ {lambda} vs analytic {expected}"
    );
}

#[test]
fn oversized_matrix_gives_clear_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = Csr::identity(100_000);
    let err = match rt.spmv(&a) {
        Ok(_) => panic!("expected bucket-miss error"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("no spmv artifact bucket"), "{err}");
}
