//! Property tests for the format-erased execution stack: every [`SpmvOp`]
//! implementation (including SELL-C-σ across several (C, σ) shapes) must
//! match the serial CSR oracle on arbitrary matrices and on the paper's
//! generator suite — for SpMV and for the fused SpMM kernels against the
//! k-independent-passes oracle — and the persistent [`WorkerPool`] must
//! be reusable across calls without leaking threads.

use phi_spmv::kernels::{ExecCtx, SpmvOp};
use phi_spmv::sched::{Policy, WorkerPool};
use phi_spmv::sparse::gen::banded::{banded_runs, BandedSpec};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::sparse::{Bcsr, Csr, Ell, Hyb, Sell};
use phi_spmv::util::prop::{arb, check};

fn assert_close(got: &[f64], want: &[f64], tag: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{tag}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        if (u - v).abs() > 1e-9 * (1.0 + v.abs()) {
            return Err(format!("{tag}: idx {i}: {u} vs {v}"));
        }
    }
    Ok(())
}

/// Every format's op for `a`, SELL in several shapes.
fn all_ops(a: &Csr) -> Vec<Box<dyn SpmvOp + '_>> {
    vec![
        Box::new(a),
        Box::new(Ell::from_csr(a, 0)),
        Box::new(Bcsr::from_csr(a, 8, 1)),
        Box::new(Bcsr::from_csr(a, 4, 2)),
        Box::new(Hyb::from_csr(a, 4)),
        Box::new(Sell::from_csr(a, 1, 1)),
        Box::new(Sell::from_csr(a, 4, 16)),
        Box::new(Sell::from_csr(a, 8, 64)),
        Box::new(Sell::from_csr(a, 8, 1 << 20)),
        Box::new(Sell::from_csr(a, 32, 256)),
    ]
}

#[test]
fn every_op_matches_the_serial_oracle_on_random_matrices() {
    check(
        "op-oracle",
        |rng| {
            let a = arb::csr(rng, 120, 10);
            let x = arb::vector(rng, a.ncols);
            (a, x)
        },
        |(a, x)| {
            // UFCS: with SpmvOp imported, the blanket `impl SpmvOp for &T`
            // would shadow the inherent one-argument `Csr::spmv` on the
            // `&Csr` receiver during method probing.
            let want = Csr::spmv(a, x);
            for op in all_ops(a) {
                for ctx in [
                    ExecCtx::serial(),
                    ExecCtx::pooled(4, Policy::Dynamic(16)),
                    ExecCtx::pooled(3, Policy::StaticBlock),
                ] {
                    assert_close(&op.spmv(x, &ctx), &want, &op.format_name())?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn every_op_spmm_matches_the_serial_oracle() {
    check(
        "op-spmm-oracle",
        |rng| {
            let a = arb::csr(rng, 80, 8);
            let k = 1 + rng.usize_below(6);
            let x = arb::vector(rng, a.ncols * k);
            (a, k, x)
        },
        |(a, k, x)| {
            let want = Csr::spmm(a, x, *k);
            let ctx = ExecCtx::pooled(4, Policy::Dynamic(32));
            for op in all_ops(a) {
                let got = op.spmm(x, *k, &ctx);
                assert_close(&got, &want, &format!("{} k={k}", op.format_name()))?;
            }
            Ok(())
        },
    );
}

/// The SpMM oracle the fused kernels must match: `k` *independent* CSR
/// SpMV passes, one per column of the row-major X/Y panels. (UFCS: with
/// SpmvOp imported, the blanket `&T` impl would shadow the inherent
/// one-argument `Csr::spmv`.)
fn spmm_oracle(a: &Csr, x: &[f64], k: usize) -> Vec<f64> {
    let mut y = vec![0.0f64; a.nrows * k];
    let mut xu = vec![0.0f64; a.ncols];
    for u in 0..k {
        for i in 0..a.ncols {
            xu[i] = x[i * k + u];
        }
        let yu = Csr::spmv(a, &xu);
        for i in 0..a.nrows {
            y[i * k + u] = yu[i];
        }
    }
    y
}

#[test]
fn every_fused_spmm_matches_k_independent_spmv_passes() {
    // Pattern classes with different failure modes: ragged random rows
    // (empty rows, rectangular shapes), a hub-heavy power-law web graph
    // (HYB overflow, SELL σ-windows), and a banded run-structured matrix
    // (BCSR's aligned blocks). k straddles the kernels' 16-wide column
    // blocking.
    let web = powerlaw(&PowerLawSpec {
        n: 900,
        nnz: 5_400,
        row_alpha: 1.6,
        col_alpha: 1.4,
        max_row: 120,
        seed: 13,
    });
    let band = banded_runs(&BandedSpec {
        n: 700,
        mean_row: 10.0,
        run: 8,
        locality: 0.05,
        seed: 17,
    });
    let ctx = ExecCtx::pooled(4, Policy::Dynamic(32));
    for (tag, a) in [("powerlaw", web), ("banded", band)] {
        for k in [1usize, 4, 17] {
            let x = random_vector(a.ncols * k, 31 + k as u64);
            let want = spmm_oracle(&a, &x, k);
            for op in all_ops(&a) {
                let got = op.spmm(&x, k, &ctx);
                assert_close(&got, &want, &format!("{tag} {} k={k}", op.format_name()))
                    .unwrap();
            }
        }
    }
}

#[test]
fn fused_spmm_matches_the_oracle_on_ragged_random_matrices() {
    check(
        "op-fused-spmm-oracle",
        |rng| {
            let a = arb::csr(rng, 90, 9);
            let k = [1usize, 4, 17][rng.usize_below(3)];
            let x = arb::vector(rng, a.ncols * k);
            (a, k, x)
        },
        |(a, k, x)| {
            let want = spmm_oracle(a, x, *k);
            for ctx in [ExecCtx::serial(), ExecCtx::pooled(4, Policy::Dynamic(16))] {
                for op in all_ops(a) {
                    let got = op.spmm(x, *k, &ctx);
                    assert_close(&got, &want, &format!("{} k={k}", op.format_name()))?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sell_matches_oracle_across_the_generator_suite() {
    // Representative pattern classes: quad mesh, scattered circuit,
    // power-law web graph, FEM, 2D stencil (same picks as bench_autotune).
    let suite = paper_suite();
    for idx in [0usize, 2, 7, 11, 19] {
        let entry = &suite[idx];
        let mut a = entry.generate_scaled(0.02);
        randomize_values(&mut a, entry.id as u64);
        let x = random_vector(a.ncols, 1234 + idx as u64);
        let want = a.spmv(&x);
        for (c, sigma) in [(4usize, 32usize), (8, 256), (32, 1024)] {
            let s = Sell::from_csr(&a, c, sigma);
            let got = s.spmv(&x); // serial reference
            assert_close(&got, &want, &format!("{} sell{c}-{sigma} serial", entry.name)).unwrap();
            let op: Box<dyn SpmvOp> = Box::new(s);
            let par = op.spmv(&x, &ExecCtx::pooled(4, Policy::Dynamic(16)));
            assert_close(&par, &want, &format!("{} sell{c}-{sigma} par", entry.name)).unwrap();
        }
    }
}

#[test]
fn worker_pool_reuse_two_calls_identical_results() {
    let suite = paper_suite();
    let mut a = suite[19].generate_scaled(0.02);
    randomize_values(&mut a, 7);
    let x = random_vector(a.ncols, 77);
    let want = a.spmv(&x);

    let pool = WorkerPool::new(3);
    let ctx = ExecCtx::on_pool(&pool, 4, Policy::Dynamic(32));
    let first = (&a as &dyn SpmvOp).spmv(&x, &ctx);
    let second = (&a as &dyn SpmvOp).spmv(&x, &ctx);
    assert_eq!(first, second, "consecutive calls on one pool must agree bit-for-bit");
    assert_close(&first, &want, "pooled").unwrap();

    // Dropping the pool joins its workers; a fresh pool must be unaffected
    // by the previous one's lifetime.
    drop(pool);
    let pool2 = WorkerPool::new(2);
    let third = (&a as &dyn SpmvOp).spmv(&x, &ExecCtx::on_pool(&pool2, 4, Policy::Dynamic(32)));
    assert_eq!(first, third);
}
