//! Cross-module integration tests: generators → analyses → models →
//! coordinator, exercising the paper's qualitative claims end to end.

use phi_spmv::analysis::{app_bytes_spmv, gather_stats, vector_traffic};
use phi_spmv::arch::cpu::CpuSpec;
use phi_spmv::arch::gpu::GpuSpec;
use phi_spmv::arch::{Bottleneck, PhiMachine};
use phi_spmv::coordinator::{Ctx, Experiment};
use phi_spmv::kernels::spmv_model::{spmv_profile, SpmvAnalysis, SpmvVariant};
use phi_spmv::kernels::spmm_model::{spmm_profile, SpmmAnalysis, SpmmVariant};
use phi_spmv::sparse::gen::paper_suite;
use phi_spmv::sparse::gen::randomize_values;
use phi_spmv::sparse::ordering::{apply_symmetric_permutation, rcm};
use phi_spmv::sparse::stats::ucld;

const SCALE: f64 = 1.0 / 64.0;

fn matrix(id: usize) -> phi_spmv::sparse::Csr {
    let suite = paper_suite();
    let e = suite.iter().find(|e| e.id == id).unwrap();
    let mut a = e.generate_scaled(SCALE);
    randomize_values(&mut a, id as u64);
    a
}

fn best_gflops(a: &phi_spmv::sparse::Csr, v: SpmvVariant) -> f64 {
    let m = PhiMachine::se10p();
    let an = SpmvAnalysis::compute(a, 61);
    m.best_config(&spmv_profile(a, v, &an), &[60, 61]).2.gflops()
}

#[test]
fn claim_spmv_is_latency_bound_for_most_matrices() {
    // §4.2: most instances gain from the 4th thread (latency-bound); the
    // model must attribute a latency bottleneck to a scattered matrix.
    let a = matrix(4); // mac_econ: scattered, low UCLD
    let m = PhiMachine::se10p();
    let an = SpmvAnalysis::compute(&a, 61);
    let w = spmv_profile(&a, SpmvVariant::O3, &an);
    let e = m.estimate(61, 3, &w);
    assert_eq!(e.bottleneck, Bottleneck::MemoryLatency, "got {}", e.bottleneck);
    // 4th thread helps (compare at 60 cores to dodge the 61×4 penalty).
    let t3 = m.estimate(60, 3, &w).time_s;
    let t4 = m.estimate(60, 4, &w).time_s;
    assert!(t4 < t3, "4th thread should help a latency-bound instance");
}

#[test]
fn claim_spmv_ceiling_30gflops() {
    // §4.2: flop:byte = 1/6 at ~183 GB/s caps SpMV around 30 GFlop/s; no
    // suite matrix may exceed it in the model.
    for id in [6, 12, 18, 20] {
        let a = matrix(id);
        let g = best_gflops(&a, SpmvVariant::O3);
        assert!(g < 30.0, "matrix {id}: {g} GFlop/s exceeds the paper ceiling");
        assert!(g > 0.5, "matrix {id}: {g} GFlop/s implausibly low");
    }
}

#[test]
fn claim_spmm_breaks_spmv_ceiling() {
    // §5: SpMM k=16 multiplies the flop:byte ratio — the same matrix must
    // go far beyond the SpMV ceiling.
    let a = matrix(12); // pwtk, the paper's 128 GFlop/s instance
    let m = PhiMachine::se10p();
    let spmv = best_gflops(&a, SpmvVariant::O3);
    let an = SpmmAnalysis::compute(&a, 61, 16);
    let spmm = m
        .best_config(&spmm_profile(&a, SpmmVariant::Nrngo, &an), &[60, 61])
        .2
        .gflops();
    assert!(spmm > 3.0 * spmv, "spmm {spmm} vs spmv {spmv}");
    assert!((60.0..160.0).contains(&spmm), "spmm {spmm} out of paper range");
}

#[test]
fn claim_ucld_correlates_with_o3_speedup() {
    // Fig. 5: across the suite, the -O3/-O1 speedup should correlate
    // positively with UCLD (Spearman-ish sign check on the extremes).
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for e in paper_suite() {
        let mut a = e.generate_scaled(SCALE);
        randomize_values(&mut a, e.id as u64);
        let speedup = best_gflops(&a, SpmvVariant::O3) / best_gflops(&a, SpmvVariant::O1);
        pts.push((ucld(&a), speedup));
    }
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let lo: f64 = pts[..5].iter().map(|p| p.1).sum::<f64>() / 5.0;
    let hi: f64 = pts[pts.len() - 5..].iter().map(|p| p.1).sum::<f64>() / 5.0;
    assert!(
        hi > lo * 1.3,
        "high-UCLD speedup {hi:.2} not clearly above low-UCLD {lo:.2}"
    );
}

#[test]
fn claim_gather_issues_bounded_by_group_size() {
    for id in [2, 8, 15] {
        let a = matrix(id);
        let g = gather_stats(&a);
        assert!(g.gathers_per_iter >= 1.0 - 1e-9);
        assert!(g.gathers_per_iter <= 8.0 + 1e-9);
        assert!(g.gather_issues <= a.nnz() as u64);
    }
}

#[test]
fn claim_rcm_improves_banded_fem_not_webgraph() {
    // Fig. 8's asymmetry: FEM/banded matrices benefit (or stay flat);
    // power-law web graphs degrade or stay flat — and vector access moves
    // the same direction as performance.
    let fem = matrix(17); // F1: the paper's biggest RCM winner
    let web = matrix(8); // webbase-1M
    let (f_before, f_after) = {
        let p = rcm(&fem);
        let b = apply_symmetric_permutation(&fem, &p);
        (
            vector_traffic(&fem, 61, 64, 8).vector_access(),
            vector_traffic(&b, 61, 64, 8).vector_access(),
        )
    };
    assert!(
        f_after <= f_before * 1.05,
        "RCM should not inflate FEM vector access: {f_before:.2} → {f_after:.2}"
    );
    let (w_before, w_after) = {
        let p = rcm(&web);
        let b = apply_symmetric_permutation(&web, &p);
        (
            vector_traffic(&web, 61, 64, 8).vector_access(),
            vector_traffic(&b, 61, 64, 8).vector_access(),
        )
    };
    // Web graphs are RCM-hostile: no big win expected.
    assert!(
        w_after > w_before * 0.7,
        "web graph should not benefit hugely: {w_before:.2} → {w_after:.2}"
    );
}

#[test]
fn claim_architecture_ranking_holds() {
    // Fig. 10 shape: Phi ≥ K20 ≥ C2050 on a bandwidth-friendly FEM SpMV,
    // and Sandy ≈ 2× Westmere.
    let a = matrix(12);
    let app = app_bytes_spmv(&a);
    let cpu_lines = vector_traffic(&a, 1, 64, 8).lines_infinite as f64;
    let row_lens: Vec<usize> = (0..a.nrows).map(|i| a.row_nnz(i)).collect();
    let phi = best_gflops(&a, SpmvVariant::O3);
    let sandy = CpuSpec::sandy().spmv_estimate(a.nnz(), a.nrows, cpu_lines, app).gflops();
    let westmere = CpuSpec::westmere().spmv_estimate(a.nnz(), a.nrows, cpu_lines, app).gflops();
    let util = GpuSpec::k20().warp_utilization(row_lens.iter().copied());
    let u = ucld(&a).clamp(0.15, 1.0);
    let k20 = GpuSpec::k20().spmv_estimate(a.nnz(), a.nrows, util, u, app).gflops();
    let c2050 = GpuSpec::c2050().spmv_estimate(a.nnz(), a.nrows, util, u, app).gflops();
    assert!(phi > sandy, "phi {phi} vs sandy {sandy}");
    assert!(k20 > c2050, "k20 {k20} vs c2050 {c2050}");
    let ratio = sandy / westmere;
    assert!((1.4..3.0).contains(&ratio), "sandy/westmere {ratio}");
}

#[test]
fn coordinator_reports_save_and_parse() {
    let dir = std::env::temp_dir().join(format!("phi-int-{}", std::process::id()));
    let ctx = Ctx {
        scale: SCALE,
        out_dir: dir.clone(),
        verbose: false,
        ..Ctx::default()
    };
    let r = Experiment::run("fig5", &ctx).unwrap();
    r.save(&dir).unwrap();
    let json = std::fs::read_to_string(dir.join("fig5.json")).unwrap();
    let parsed = phi_spmv::util::json::Json::parse(&json).unwrap();
    assert_eq!(parsed.get("matrices").unwrap().as_arr().unwrap().len(), 22);
    let csv = std::fs::read_to_string(dir.join("fig5.csv")).unwrap();
    assert_eq!(csv.lines().count(), 23); // header + 22 rows
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mtx_file_to_model_pipeline() {
    // Full path: write a matrix to MatrixMarket, load it back, order it,
    // model it — the downstream-user workflow.
    let dir = std::env::temp_dir().join(format!("phi-mtx-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let a = matrix(6);
    let path = dir.join("cant.mtx");
    phi_spmv::sparse::mm_io::write_mtx(&path, &a).unwrap();
    let b = phi_spmv::sparse::mm_io::load_mtx(&path).unwrap();
    assert_eq!(a, b);
    let g = best_gflops(&b, SpmvVariant::O3);
    assert!(g > 0.0 && g.is_finite());
    std::fs::remove_dir_all(&dir).ok();
}
