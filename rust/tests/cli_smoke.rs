//! CLI smoke tests: run the built binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_phi-spmv"))
}

#[test]
fn help_lists_experiments() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in ["table1", "fig4", "fig10", "table2"] {
        assert!(text.contains(id), "help missing {id}");
    }
}

#[test]
fn list_prints_all_ids() {
    let out = bin().arg("list").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert_eq!(text.lines().count(), 11);
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("fig99").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn table1_runs_and_saves() {
    let dir = std::env::temp_dir().join(format!("phi-cli-{}", std::process::id()));
    let out = bin()
        .args(["table1", "--scale", "0.01", "--out", dir.to_str().unwrap(), "--quiet"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mesh_2048"));
    assert!(dir.join("table1.json").exists());
    assert!(dir.join("table1.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_native_spmv_reports_gflops() {
    let out = bin()
        .args(["run", "--matrix", "cant", "--scale", "0.02", "--kernel", "spmv"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("GFlop/s"), "{text}");
}

#[test]
fn run_unknown_matrix_fails() {
    let out = bin().args(["run", "--matrix", "nope"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown matrix"));
}
