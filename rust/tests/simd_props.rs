//! Vectorized-kernel properties: every format × workload must produce
//! the same result under the detected ISA as under the forced-portable
//! scalar path (the oracle), across matrix shapes chosen to hit the
//! vector kernels' edges — remainder lanes, empty rows, chunk widths
//! that don't divide the lane count, and k widths around the SpMM
//! column-block boundaries.
//!
//! Run under `PALLAS_ISA=portable` this degenerates to scalar-vs-scalar
//! (still a valid identity); CI runs it both ways.

use phi_spmv::kernels::{ExecCtx, IsaLevel, SpmvOp, Workload};
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::{Coo, Csr};
use phi_spmv::tuner::{exec::prepare, Format};

/// Relative closeness: the vector kernels reassociate sums (4/8-wide
/// partials, FMA contraction), so exact equality is not the contract.
fn assert_close(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        assert!(
            (u - v).abs() <= 1e-9 * v.abs().max(1.0),
            "{what}[{i}]: {u} vs {v}"
        );
    }
}

/// The matrices the kernels must agree on: a banded stencil (uniform
/// short rows), a power-law graph (ragged rows, hubs, empties), and a
/// hand-built edge case whose row count is coprime to every lane width
/// and whose rows include empty, length-1 and length-9 shapes.
fn matrices() -> Vec<(&'static str, Csr)> {
    let mut stencil = stencil_2d(13, 9);
    randomize_values(&mut stencil, 5);
    let ragged = powerlaw(&PowerLawSpec {
        n: 500,
        nnz: 3000,
        row_alpha: 1.6,
        col_alpha: 1.4,
        max_row: 80,
        seed: 7,
    });
    let mut edges = Coo::new(37, 41);
    for i in 0..37 {
        match i % 4 {
            0 => {} // empty row
            1 => edges.push(i, i % 41, 1.5 + i as f64),
            _ => {
                for j in 0..9 {
                    edges.push(i, (i * 3 + j * 5) % 41, 0.25 * (i + j) as f64 - 3.0);
                }
            }
        }
    }
    vec![("stencil", stencil), ("powerlaw", ragged), ("edges", edges.to_csr())]
}

fn formats() -> Vec<Format> {
    vec![
        Format::Csr,
        Format::Ell,
        Format::Hyb { width: 4 },
        Format::Bcsr { r: 4, c: 2 },
        // SELL chunks below, at, and beyond the lane widths: c = 3 never
        // vectorizes, c = 4 is exactly one AVX2 vector, c = 8 one
        // AVX-512 (or two AVX2) vectors, c = 32 the kernels' cap.
        Format::Sell { c: 3, sigma: 64 },
        Format::Sell { c: 4, sigma: 64 },
        Format::Sell { c: 8, sigma: 256 },
        Format::Sell { c: 32, sigma: 256 },
    ]
}

/// The k sweep crosses the SpMM kernels' column-block boundaries: 1
/// (SpMV), 3 (scalar tail only), 8 (two AVX2 vectors), 16 (a full
/// block), 17 (full block + remainder lane).
const KS: [usize; 5] = [1, 3, 8, 16, 17];

#[test]
fn vectorized_kernels_match_the_portable_oracle() {
    let detected = ExecCtx::serial();
    let portable = ExecCtx::serial().with_isa(IsaLevel::Portable);
    for (name, a) in matrices() {
        for format in formats() {
            let op = prepare(&a, format);
            for k in KS {
                let what = format!("{name}/{format}/k{k}");
                let x = random_vector(a.ncols * k, 11);
                let mut got = vec![0.0f64; a.nrows * k];
                let mut want = vec![0.0f64; a.nrows * k];
                if k > 1 {
                    op.spmm_into(&x, &mut got, k, &detected);
                    op.spmm_into(&x, &mut want, k, &portable);
                } else {
                    op.spmv_into(&x, &mut got, &detected);
                    op.spmv_into(&x, &mut want, &portable);
                }
                assert_close(&got, &want, &what);
                // And the portable path itself agrees with the reference
                // triplet product, so "both paths wrong the same way"
                // cannot pass.
                let reference = if k > 1 { a.spmm(&x, k) } else { a.spmv(&x) };
                assert_close(&want, &reference, &format!("{what}/oracle"));
            }
        }
    }
}

#[test]
fn pooled_execution_agrees_across_isa_levels() {
    let mut a = stencil_2d(21, 17);
    randomize_values(&mut a, 13);
    let detected = ExecCtx::pooled(2, Policy::Dynamic(16));
    let portable = ExecCtx::pooled(2, Policy::Dynamic(16)).with_isa(IsaLevel::Portable);
    for format in formats() {
        let op = prepare(&a, format);
        let k = 4;
        let x = random_vector(a.ncols * k, 3);
        let mut got = vec![0.0f64; a.nrows * k];
        let mut want = vec![0.0f64; a.nrows * k];
        op.spmm_into(&x, &mut got, k, &detected);
        op.spmm_into(&x, &mut want, k, &portable);
        assert_close(&got, &want, &format!("pooled/{format}"));
    }
}

#[test]
fn isa_level_parse_name_and_order_are_consistent() {
    for isa in [IsaLevel::Portable, IsaLevel::Avx2, IsaLevel::Avx512] {
        assert_eq!(IsaLevel::parse(isa.name()), Some(isa), "name must parse back");
    }
    assert_eq!(IsaLevel::parse("scalar"), Some(IsaLevel::Portable));
    assert_eq!(IsaLevel::parse("AVX2"), Some(IsaLevel::Avx2), "parse is case-insensitive");
    assert_eq!(IsaLevel::parse("knc"), None);
    assert!(IsaLevel::Portable < IsaLevel::Avx2 && IsaLevel::Avx2 < IsaLevel::Avx512);
    assert_eq!(IsaLevel::Portable.lanes(), 1);
    assert!(IsaLevel::Avx2.lanes() < IsaLevel::Avx512.lanes());
}

#[test]
fn detection_is_bounded_and_sanitize_clamps() {
    let detected = IsaLevel::detect();
    assert!(detected <= IsaLevel::available(), "detect can never exceed the host");
    // A context asking for more than the host has is clamped, not
    // trusted — forcing Avx512 on a portable host must still compute.
    let mut a = stencil_2d(9, 9);
    randomize_values(&mut a, 1);
    let x = random_vector(a.ncols, 2);
    let mut y = vec![0.0f64; a.nrows];
    let greedy = ExecCtx::serial().with_isa(IsaLevel::Avx512);
    a.spmv_into(&x, &mut y, &greedy);
    assert_close(&y, &a.spmv(&x), "clamped-isa spmv");
}
