//! Integration tests for the multi-tenant fleet: budget-forced LRU
//! eviction with correct answers after re-materialization, background
//! drift re-tuning with hot swaps invisible to concurrent clients, and
//! arrival-rate-adaptive batch width.

use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use phi_spmv::fleet::{BatchConfig, Fleet, FleetConfig, FleetEvent, RetuneConfig};
use phi_spmv::kernels::Workload;
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::Csr;
use phi_spmv::tuner::Tuner;

fn matrix(seed: u64, n: usize) -> Arc<Csr> {
    let mut a = stencil_2d(n, n);
    randomize_values(&mut a, seed);
    Arc::new(a)
}

fn assert_close(got: &[f64], want: &[f64], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}");
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        assert!((u - v).abs() < 1e-9 * (1.0 + v.abs()), "{tag}: idx {i}: {u} vs {v}");
    }
}

/// The acceptance scenario in one piece: ≥ 8 registered matrices under a
/// budget that forces eviction, every answer correct across
/// evict/re-materialize cycles, and a drift-injected entry re-tuned and
/// hot-swapped *by the background maintenance thread* while concurrent
/// clients observe only natural-order-correct results.
#[test]
fn fleet_serves_eight_matrices_under_eviction_and_survives_a_hot_swap() {
    // Distinct sizes → distinct fingerprints → one tuned decision pair
    // per matrix.
    let mats: Vec<(String, Arc<Csr>)> =
        (0..8).map(|i| (format!("m{i}"), matrix(40 + i as u64, 20 + i))).collect();
    let total_csr: usize = mats.iter().map(|(_, a)| a.storage_bytes()).sum();
    let budget = total_csr / 2;
    let config = FleetConfig {
        memory_budget_bytes: budget,
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        retune: RetuneConfig {
            enabled: true,
            interval: Duration::from_millis(25),
            ..RetuneConfig::default()
        },
        // Width adaptation is exercised by its own test; freeze it here
        // so the drift assertions race nothing.
        batch: BatchConfig { min_samples: usize::MAX, ..BatchConfig::default() },
        ..FleetConfig::default()
    };
    let fleet = Fleet::new(config, Tuner::quick());
    for (id, a) in &mats {
        fleet.register(id, a.clone()).unwrap();
    }

    // The budget must actually have bitten, and the warm set must fit it.
    let early = fleet.stats();
    assert!(early.evictions >= 2, "a half-size budget must evict (got {})", early.evictions);
    assert!(fleet.storage_bytes() <= budget);

    // Every entry answers correctly — the evicted ones re-materialize
    // from their kept decisions without a re-search.
    let (_, misses_before) = fleet.tuner_counters();
    for (s, (id, a)) in mats.iter().enumerate() {
        let x = random_vector(a.ncols, 500 + s as u64);
        let want = Csr::spmv(a, &x);
        let resp = fleet.call(id, x).unwrap();
        assert_close(&resp.y, &want, id);
    }
    let (_, misses_after) = fleet.tuner_counters();
    assert_eq!(misses_after, misses_before, "re-materialization must never re-search");
    let stats = fleet.stats();
    assert!(stats.rematerializations >= 2, "cold entries must have come back on demand");

    // Drift injection: inflate the recorded GFlop/s of one entry's
    // decisions by 10^6 — every future window now contradicts them.
    let hot = "m3";
    let hot_a = mats.iter().find(|(id, _)| id == hot).unwrap().1.clone();
    fleet.skew_recorded_gflops(hot, Workload::Spmv, 1e6).unwrap();
    fleet.skew_recorded_gflops(hot, Workload::Spmm { k: 16 }, 1e6).unwrap();

    // Concurrent clients across several entries — including the one the
    // background thread will hot-swap under them — check every response
    // against the serial oracle.
    let wrong = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for (t, (id, a)) in mats.iter().enumerate().take(4) {
            let fleet = &fleet;
            let wrong = &wrong;
            let calls = if id == hot { 120usize } else { 40 };
            scope.spawn(move || {
                for s in 0..calls {
                    let x = random_vector(a.ncols, 9_000 + (t * 1_000 + s) as u64);
                    let want = Csr::spmv(a, &x);
                    let resp = fleet.call(id, x).unwrap();
                    for (u, v) in resp.y.iter().zip(&want) {
                        if (u - v).abs() >= 1e-9 * (1.0 + v.abs()) {
                            wrong.fetch_add(1, AtomicOrdering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    assert_eq!(wrong.load(AtomicOrdering::Relaxed), 0, "no client may ever see a wrong answer");

    // The background thread must confirm the drift and hot-swap a fresh
    // decision in; keep feeding the window evidence until it does.
    let deadline = Instant::now() + Duration::from_secs(20);
    while fleet.stats().retunes == 0 && Instant::now() < deadline {
        for s in 0..5u64 {
            let x = random_vector(hot_a.ncols, 77_000 + s);
            let want = Csr::spmv(&hot_a, &x);
            let resp = fleet.call(hot, x).unwrap();
            assert_close(&resp.y, &want, "hot entry during drift window");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let stats = fleet.stats();
    assert!(stats.retunes >= 1, "background thread must re-tune the drift-injected entry");
    let events = fleet.drain_events();
    let retuned: Vec<_> = events
        .iter()
        .filter(|e| matches!(e, FleetEvent::Retuned { id, .. } if id == hot))
        .collect();
    assert!(!retuned.is_empty(), "a Retuned event must name the injected entry");

    // And the entry still answers correctly after the swap.
    let x = random_vector(hot_a.ncols, 123_456);
    let want = Csr::spmv(&hot_a, &x);
    let resp = fleet.call(hot, x).unwrap();
    assert_close(&resp.y, &want, "hot entry after the swap");

    let final_stats = fleet.shutdown();
    assert_eq!(final_stats.entries.len(), 8);
    // Fleet aggregates are sums of the per-entry path counters.
    let flops_sum: f64 =
        final_stats.entries.iter().map(|e| e.spmv.flops + e.spmm.flops).sum();
    assert_eq!(final_stats.flops(), flops_sum);
    assert!(final_stats.served() > 0);
}

#[test]
fn adaptive_width_walks_the_ladder_with_injected_load_shapes() {
    let a = matrix(7, 28);
    let config = FleetConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(4),
        // Manual maintenance only: the test decides when adaptation runs.
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        batch: BatchConfig { ladder: vec![1, 4, 8, 16], min_samples: 8, hysteresis: 1.25 },
        ..FleetConfig::default()
    };
    let fleet = Fleet::new(config, Tuner::quick());
    fleet.register("m", a.clone()).unwrap();
    assert_eq!(fleet.current_max_batch("m"), Some(4));

    // Fast load shape, injected rather than timed: a 0.2 ms mean gap is
    // 5000 Hz — 20 expected arrivals per 4 ms window, filling the top
    // rung. No request has recorded a wall-clock arrival yet, so the
    // tracker's idle bound is inert and the estimate is exactly 1/EMA:
    // the upshift is deterministic on any machine, loaded or not.
    fleet.inject_arrival_gaps("m", 0.0002, 20).unwrap();
    fleet.maintain_now();
    assert_eq!(fleet.current_max_batch("m"), Some(16), "fast shape must upshift to the top rung");
    let (_, spmm_decision) = fleet.decisions("m").unwrap();
    assert_eq!(
        spmm_decision.workload,
        Workload::Spmm { k: 16 },
        "the batch path must serve a decision tuned at the new width"
    );
    let swaps = fleet.path_swaps("m").unwrap();
    assert!(swaps.1 >= 1, "upshift must hot-swap the SpMM path");
    let events = fleet.drain_events();
    assert!(
        events.iter().any(|e| matches!(
            e,
            FleetEvent::WidthChanged { id, to: 16, .. } if id == "m"
        )),
        "a WidthChanged event must record the move"
    );

    // The widened entry actually serves — concurrent submissions may
    // fuse into any widths the batcher picks, and every answer must
    // still be its own oracle.
    let inputs: Vec<Vec<f64>> =
        (0..24).map(|s| random_vector(a.ncols, 3_000 + s as u64)).collect();
    let subs: Vec<_> =
        inputs.iter().map(|x| fleet.submit("m", x.clone()).unwrap()).collect();
    for (x, sub) in inputs.iter().zip(subs) {
        let resp = sub.recv().unwrap();
        assert_close(&resp.y, &Csr::spmv(&a, x), "serving at the widened rung");
    }

    // Slow shape: half-second gaps dominate the EMA, so the estimate
    // collapses no matter what the wall clock did in between (real
    // arrivals above only make the idle bound pull it lower still) and
    // the width falls through the hysteresis all the way to 1.
    fleet.inject_arrival_gaps("m", 0.5, 30).unwrap();
    fleet.maintain_now();
    assert_eq!(fleet.current_max_batch("m"), Some(1), "slow shape must downshift");

    // Correctness is untouched by the walking width.
    let x = random_vector(a.ncols, 6_000);
    let want = Csr::spmv(&a, &x);
    let resp = fleet.call("m", x).unwrap();
    assert_close(&resp.y, &want, "after downshift");
    fleet.shutdown();
}

#[test]
fn adapted_width_survives_eviction_and_rematerialization() {
    let a = matrix(8, 24);
    let b = matrix(9, 26);
    let budget = a.storage_bytes() + b.storage_bytes();
    let config = FleetConfig {
        // Budget fits roughly one entry once payload overheads land, so
        // registering "b" evicts "a".
        memory_budget_bytes: budget / 2,
        max_batch: 4,
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        batch: BatchConfig { ladder: vec![1, 4, 8, 16], min_samples: 8, hysteresis: 1.25 },
        ..FleetConfig::default()
    };
    let fleet = Fleet::new(config, Tuner::quick());
    fleet.register("a", a.clone()).unwrap();

    // Upshift "a" with an injected fast load shape (deterministic — see
    // the ladder test), then force it cold by registering "b".
    fleet.inject_arrival_gaps("a", 0.0002, 20).unwrap();
    fleet.maintain_now();
    assert_eq!(fleet.current_max_batch("a"), Some(16));
    fleet.register("b", b.clone()).unwrap();
    assert_eq!(fleet.is_warm("a"), Some(false), "registering b must evict the LRU entry");
    // The cold entry remembers its adapted width…
    assert_eq!(fleet.current_max_batch("a"), Some(16));

    // …and serves with it after re-materializing.
    let x = random_vector(a.ncols, 4_242);
    let want = Csr::spmv(&a, &x);
    let resp = fleet.call("a", x).unwrap();
    assert_close(&resp.y, &want, "rematerialized");
    assert_eq!(fleet.current_max_batch("a"), Some(16));
    fleet.shutdown();
}

/// Drift detection needs no wall clock either: inject the skew, feed
/// the window its evidence with ordinary calls, and run the maintenance
/// pass by hand — confirmation, re-tune and hot swap are then
/// deterministic (the background thread runs the identical pass on its
/// interval; its eventual behavior is covered by the scenario test
/// above).
#[test]
fn drift_retune_is_deterministic_under_manual_maintenance() {
    let a = matrix(11, 24);
    let config = FleetConfig {
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        batch: BatchConfig { min_samples: usize::MAX, ..BatchConfig::default() },
        ..FleetConfig::default()
    };
    let fleet = Fleet::new(config, Tuner::quick());
    fleet.register("d", a.clone()).unwrap();

    // Promise a million times what the kernels deliver: every window
    // now contradicts the SpMV decision.
    fleet.skew_recorded_gflops("d", Workload::Spmv, 1e6).unwrap();
    // Single-request calls build the window past min_window_batches.
    for s in 0..4u64 {
        let x = random_vector(a.ncols, 8_800 + s);
        let want = Csr::spmv(&a, &x);
        let resp = fleet.call("d", x).unwrap();
        assert_close(&resp.y, &want, "during the drift window");
    }
    assert_eq!(fleet.stats().retunes, 0, "no pass has run yet");
    fleet.maintain_now();
    let stats = fleet.stats();
    assert!(stats.retunes >= 1, "the manual pass must confirm the skew and re-install");
    let events = fleet.drain_events();
    assert!(
        events.iter().any(|e| matches!(e, FleetEvent::Retuned { id, .. } if id == "d")),
        "a Retuned event must name the skewed entry"
    );
    // The swapped-in decision drops the inflated promise and still
    // serves correct answers.
    let (spmv_decision, _) = fleet.decisions("d").unwrap();
    assert!(
        spmv_decision.gflops < 1e5,
        "the re-tuned decision must carry a measured figure, got {}",
        spmv_decision.gflops
    );
    let x = random_vector(a.ncols, 9_900);
    let want = Csr::spmv(&a, &x);
    let resp = fleet.call("d", x).unwrap();
    assert_close(&resp.y, &want, "after the deterministic swap");
    fleet.shutdown();
}
