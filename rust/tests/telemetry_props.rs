//! Property tests for the telemetry layer: histogram quantiles against a
//! sorted-vector oracle, exact counting under concurrent hammering,
//! journal drop-oldest accounting, the serving phase-attribution
//! identity (`queue + barrier + kernel ≈ latency`), and exporter
//! round-trips.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use phi_spmv::coordinator::{ServerConfig, SpmvServer};
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::Csr;
use phi_spmv::telemetry::metrics::Histogram;
use phi_spmv::telemetry::{
    names, prometheus_text, validate_prometheus, EventJournal, EventKind, Telemetry,
    TelemetrySnapshot,
};

fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

fn matrix(seed: u64, n: usize) -> Arc<Csr> {
    let mut a = stencil_2d(n, n);
    randomize_values(&mut a, seed);
    Arc::new(a)
}

/// Nearest-rank quantile of a sorted nanosecond sample — the oracle the
/// histogram's bucketed estimate is checked against.
fn oracle_ns(sorted: &[u64], q: f64) -> u64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank - 1]
}

#[test]
fn histogram_quantiles_track_a_sorted_oracle() {
    let mut state = 0x9e3779b97f4a7c15u64;
    let uniform: Vec<u64> = (0..4000).map(|_| 1_000 + xorshift(&mut state) % 999_000).collect();
    let log_spaced: Vec<u64> = (0..4000)
        .map(|_| {
            let octave = xorshift(&mut state) % 14;
            let base = 100u64 << octave;
            base + xorshift(&mut state) % base.max(1)
        })
        .collect();
    let constant: Vec<u64> = vec![5_000; 2000];
    let bimodal: Vec<u64> = (0..4000)
        .map(|i| if i % 10 == 0 { 10_000_000 + xorshift(&mut state) % 1_000_000 } else { 50_000 })
        .collect();
    for (tag, sample) in
        [("uniform", uniform), ("log", log_spaced), ("constant", constant), ("bimodal", bimodal)]
    {
        let h = Histogram::new();
        for &ns in &sample {
            h.record_ns(ns);
        }
        let mut sorted = sample.clone();
        sorted.sort_unstable();
        assert_eq!(h.count(), sample.len() as u64, "{tag}");
        for q in [0.5, 0.9, 0.99, 0.999] {
            let want = oracle_ns(&sorted, q) as f64 * 1e-9;
            let got = h.quantile(q);
            // The estimate is the holding bucket's upper bound: it must
            // never undershoot the true quantile and overshoots by at
            // most one sub-bucket width (≤ 25% relative).
            assert!(got >= want * 0.999, "{tag} q{q}: {got} < oracle {want}");
            assert!(got <= want * 1.26, "{tag} q{q}: {got} > 1.26 × oracle {want}");
        }
    }
}

#[test]
fn concurrent_hammer_counts_exactly() {
    let t = Telemetry::new();
    let c = t.metrics.counter("hammer_total");
    let h = t.metrics.histogram("hammer_seconds");
    const THREADS: u64 = 8;
    const PER: u64 = 5_000;
    thread::scope(|s| {
        for w in 0..THREADS {
            let c = c.clone();
            let h = h.clone();
            s.spawn(move || {
                for i in 0..PER {
                    c.inc();
                    // Unique-per-observation values so the expected sum
                    // is computable exactly.
                    h.record_ns(1_000 + (w * PER + i));
                }
            });
        }
    });
    let n = THREADS * PER;
    assert_eq!(c.get(), n, "counter must not lose increments under contention");
    assert_eq!(h.count(), n, "histogram count must be exact under contention");
    let expected_ns = n * 1_000 + (n - 1) * n / 2;
    assert!(
        (h.sum_s() - expected_ns as f64 * 1e-9).abs() < 1e-12,
        "histogram sum must be exact: {} vs {}",
        h.sum_s(),
        expected_ns as f64 * 1e-9
    );
}

#[test]
fn concurrent_journal_publishes_are_totally_ordered() {
    let t = Telemetry::with_event_capacity(64);
    thread::scope(|s| {
        for w in 0..4usize {
            let t = &t;
            s.spawn(move || {
                for i in 0..200usize {
                    t.publish(EventKind::Evicted { id: format!("w{w}e{i}"), bytes: i });
                }
            });
        }
    });
    assert_eq!(t.journal.published(), 800);
    assert_eq!(t.journal.dropped(), 800 - 64);
    assert_eq!(t.journal.len(), 64);
    assert_eq!(t.journal.counts(), vec![("evicted", 800)]);
    let recent = t.journal.recent(64);
    assert_eq!(recent.len(), 64);
    for pair in recent.windows(2) {
        assert_eq!(pair[1].seq, pair[0].seq + 1, "retained tail must be gap-free");
    }
    assert_eq!(recent.last().unwrap().seq, 799);
}

#[test]
fn journal_drop_oldest_keeps_the_tail_and_reports_the_blind_spot() {
    let j = EventJournal::new(8);
    let mut sub = j.subscribe_from_start();
    for i in 0..20usize {
        j.publish(EventKind::Evicted { id: format!("m{i}"), bytes: i });
    }
    assert_eq!((j.published(), j.dropped(), j.len(), j.capacity()), (20, 12, 8, 8));
    let (events, missed) = sub.poll(&j);
    assert_eq!(missed, 12, "a slow reader must learn how much history it lost");
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (12..20).collect::<Vec<u64>>());
    // Lifetime per-kind counts survive eviction.
    assert_eq!(j.counts(), vec![("evicted", 20)]);
    let (events, missed) = sub.poll(&j);
    assert!(events.is_empty() && missed == 0, "a caught-up reader sees nothing twice");
}

#[test]
fn serving_phase_spans_sum_to_request_latency() {
    let a = matrix(42, 100);
    let server = SpmvServer::start(
        a.clone(),
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    );
    let telemetry = server.telemetry();
    let client = server.client();
    let mut wall = Vec::new();
    let mut phase = Vec::new();
    // Concurrent bursts (fused batches share barrier/kernel spans) …
    for round in 0..5u64 {
        let rxs: Vec<_> = (0..8)
            .map(|s| client.submit(random_vector(a.ncols, round * 100 + s)).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            wall.push(resp.latency.as_secs_f64());
            phase.push(resp.phases.total_s());
        }
    }
    // … then sequential lone requests (the SpMV path).
    for s in 0..10u64 {
        let resp = client.call(random_vector(a.ncols, 900 + s)).unwrap();
        wall.push(resp.latency.as_secs_f64());
        phase.push(resp.phases.total_s());
    }
    assert_eq!(wall.len(), 50);
    // The three phases partition the latency: their sum can trail the
    // wall clock only by the post-kernel bookkeeping sliver.
    for (i, (&w, &p)) in wall.iter().zip(&phase).enumerate() {
        assert!(w + 10e-6 >= p, "request {i}: phases {p} exceed latency {w}");
    }
    let n = wall.len() as f64;
    let mean_wall = wall.iter().sum::<f64>() / n;
    let mean_phase = phase.iter().sum::<f64>() / n;
    let slack = (0.10 * mean_wall).max(500e-6);
    assert!(
        (mean_wall - mean_phase).abs() <= slack,
        "phase attribution must explain the latency: mean wall {mean_wall}, mean phases \
         {mean_phase}, slack {slack}"
    );
    // The engine recorded every request into the shared histograms …
    assert_eq!(telemetry.metrics.histogram(names::REQUEST_LATENCY).count(), 50);
    assert_eq!(telemetry.metrics.counter(names::REQUESTS_SERVED).get(), 50);
    let hist_phase_sum: f64 = [names::PHASE_QUEUE, names::PHASE_BARRIER, names::PHASE_KERNEL]
        .iter()
        .map(|name| telemetry.metrics.histogram(name).sum_s())
        .sum();
    let total_phase: f64 = phase.iter().sum();
    assert!(
        (hist_phase_sum - total_phase).abs() < 1e-6,
        "histogram sums must match the per-response attributions: {hist_phase_sum} vs \
         {total_phase}"
    );
    // … and the path counters absorbed the same request-seconds.
    let stats = server.shutdown();
    let attr = stats.spmv.queue_s
        + stats.spmv.barrier_s
        + stats.spmv.kernel_s
        + stats.spmm.queue_s
        + stats.spmm.barrier_s
        + stats.spmm.kernel_s;
    assert!(
        (attr - total_phase).abs() <= 1e-9 + 1e-9 * total_phase.abs(),
        "PathStats phase fields must sum the per-request phases: {attr} vs {total_phase}"
    );
    assert!(stats.spmm.kernel_s > 0.0, "fused batches must attribute kernel time");
}

#[test]
fn snapshot_and_exposition_survive_a_serving_run() {
    let a = matrix(7, 40);
    let server = SpmvServer::start(a.clone(), ServerConfig::default());
    let telemetry = server.telemetry();
    let client = server.client();
    for s in 0..10u64 {
        client.call(random_vector(a.ncols, s)).unwrap();
    }
    server.shutdown();

    // JSON snapshot: parse ∘ print is the identity, and the sections
    // reflect the run.
    let snap = TelemetrySnapshot::capture(&telemetry);
    let text = snap.to_pretty();
    let back = TelemetrySnapshot::parse(&text).unwrap();
    assert_eq!(back.json.to_string(), snap.json.to_string(), "round-trip must be lossless");
    let served = back
        .json
        .get("counters")
        .and_then(|c| c.get(names::REQUESTS_SERVED))
        .and_then(|v| v.as_usize());
    assert_eq!(served, Some(10));
    let latency_count = back
        .json
        .get("histograms")
        .and_then(|h| h.get(names::REQUEST_LATENCY))
        .and_then(|h| h.get("count"))
        .and_then(|v| v.as_usize());
    assert_eq!(latency_count, Some(10));
    assert!(back.json.get("pool").is_some(), "capture() must carry the global pool probe");

    // Prometheus text exposition: the line validator accepts every line
    // and sees the serving series.
    let prom = prometheus_text(&telemetry, None);
    let samples = validate_prometheus(&prom).unwrap();
    assert!(samples >= 10, "expected a populated exposition, got {samples} samples:\n{prom}");
    assert!(prom.contains("phi_request_latency_seconds_bucket"));
    assert!(prom.contains("phi_requests_served_total 10"));
}
