//! End-to-end properties of request-scoped tracing through the serving
//! stack: trace-id uniqueness under concurrent clients, the causal span
//! tree (intake admission → shard fan-out → batch → kernel) rooted at
//! the request and closed, span accounting against the client-observed
//! latency on the sharded path, exact 1-in-N sampler hit rates (and the
//! zero-rate off switch), and a Chrome trace-event export that round-trips
//! through the crate's own JSON parser.
//!
//! The tracer's single-layer behaviors (buffer eviction, forced tenants,
//! post-hoc span recording) are unit-tested in `telemetry::trace`; these
//! tests only assert what emerges from the layers composed.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Instant;

use phi_spmv::fleet::shard::ShardConfig;
use phi_spmv::fleet::{Admission, Fleet, FleetConfig, Intake, RetuneConfig, TenantBudget};
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::Csr;
use phi_spmv::telemetry::SpanRecord;
use phi_spmv::tuner::{Tuner, TunerConfig, TuningCache};
use phi_spmv::util::json::Json;

fn matrix(seed: u64, n: usize) -> Arc<Csr> {
    let mut a = stencil_2d(n, n);
    randomize_values(&mut a, seed);
    Arc::new(a)
}

/// A quiet fleet (no retune thread); `shards` forces the shard plan on
/// for every entry, `None` leaves the default single-shard threshold.
fn fleet(shards: Option<usize>) -> Fleet {
    let tuner = Tuner::new(TunerConfig::model_only(), TuningCache::in_memory());
    let mut config = FleetConfig {
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        ..FleetConfig::default()
    };
    if let Some(shards) = shards {
        config.shard = ShardConfig { threshold_nnz: 0, shards };
    }
    Fleet::new(config, tuner)
}

fn spans_of<'a>(spans: &'a [SpanRecord], trace: u64) -> Vec<&'a SpanRecord> {
    spans.iter().filter(|s| s.trace == trace).collect()
}

fn find<'a>(trace: &[&'a SpanRecord], name: &str) -> Vec<&'a SpanRecord> {
    trace.iter().filter(|s| s.name == name).copied().collect()
}

fn has_arg(span: &SpanRecord, key: &str, want: &str) -> bool {
    span.args.iter().any(|(k, v)| k == key && v.as_str() == Some(want))
}

/// Every span's parent must resolve to another span of the same trace,
/// and exactly one span (the root) may have no parent.
fn assert_tree_closed(trace: &[&SpanRecord], tag: &str) {
    let ids: BTreeSet<u64> = trace.iter().map(|s| s.span).collect();
    assert_eq!(ids.len(), trace.len(), "{tag}: duplicate span ids");
    let roots = trace.iter().filter(|s| s.parent.is_none()).count();
    assert_eq!(roots, 1, "{tag}: exactly one root span");
    for s in trace {
        if let Some(p) = s.parent {
            assert!(ids.contains(&p), "{tag}: span {} has dangling parent {p}", s.span);
        }
    }
}

#[test]
fn concurrent_fleet_clients_get_unique_trace_ids() {
    let fleet = fleet(None);
    let a = matrix(3, 16);
    fleet.register("t", a.clone()).unwrap();
    let telemetry = fleet.telemetry();
    telemetry.tracer.set_sample_every(1);

    const CLIENTS: usize = 8;
    const ROUNDS: usize = 16;
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let fleet = &fleet;
            let a = &a;
            s.spawn(move || {
                for round in 0..ROUNDS {
                    let x = random_vector(a.ncols, (100 + c * ROUNDS + round) as u64);
                    fleet.call("t", x).expect("healthy fleet must answer");
                }
            });
        }
    });

    let spans = telemetry.tracer.spans();
    let roots = find(&spans.iter().collect::<Vec<_>>(), "request");
    assert_eq!(roots.len(), CLIENTS * ROUNDS, "every request yields one root");
    let ids: BTreeSet<u64> = roots.iter().map(|s| s.trace).collect();
    assert_eq!(ids.len(), roots.len(), "duplicate trace ids under concurrency");
    assert_eq!(telemetry.tracer.stats().sampled, (CLIENTS * ROUNDS) as u64);
    // Every recorded span belongs to a request whose root survived.
    for s in &spans {
        assert!(ids.contains(&s.trace), "span {} orphaned from trace {}", s.name, s.trace);
    }
    fleet.shutdown();
}

#[test]
fn intake_trace_tree_is_rooted_at_request_and_closed() {
    let fleet = fleet(None);
    let a = matrix(5, 14);
    fleet.register("acme", a.clone()).unwrap();
    let telemetry = fleet.telemetry();
    telemetry.tracer.set_sample_every(1);
    let intake = Intake::new(fleet, TenantBudget::unlimited());

    match intake.submit("acme", random_vector(a.ncols, 7)).unwrap() {
        Admission::Admitted(ticket) => {
            ticket.recv().expect("admitted request must be answered");
        }
        Admission::Shed { reason } => panic!("unlimited budget shed: {reason:?}"),
    }

    let spans = telemetry.tracer.spans();
    let all: Vec<_> = spans.iter().collect();
    let root = find(&all, "request").pop().expect("root span");
    assert_eq!(root.parent, None);
    assert!(has_arg(root, "tenant", "acme"), "root carries the tenant: {:?}", root.args);
    let trace = spans_of(&spans, root.trace);
    assert_tree_closed(&trace, "admitted request");

    let admission = find(&trace, "admission").pop().expect("admission span");
    assert_eq!(admission.parent, Some(root.span), "admission hangs off the root");
    assert!(has_arg(admission, "verdict", "admitted"), "args: {:?}", admission.args);

    let shard = find(&trace, "shard").pop().expect("even one-shard entries trace the leg");
    assert_eq!(shard.parent, Some(root.span));
    let batch = find(&trace, "batch").pop().expect("batch span");
    assert_eq!(batch.parent, Some(shard.span), "batch continues the shard leg");
    let kernel = find(&trace, "kernel").pop().expect("kernel span");
    assert_eq!(kernel.parent, Some(batch.span), "kernel nests under its batch");
    assert!(
        kernel.args.iter().any(|(k, _)| k == "gbps"),
        "kernel span carries roofline args: {:?}",
        kernel.args
    );

    // A shed is a completed (if short) trace too: root + refused
    // admission, nothing else — and the tree still closes.
    intake.set_budget("acme", TenantBudget { max_inflight: 0, ..TenantBudget::unlimited() });
    match intake.submit("acme", random_vector(a.ncols, 8)).unwrap() {
        Admission::Shed { .. } => {}
        Admission::Admitted(_) => panic!("zero in-flight budget must shed"),
    }
    let spans = telemetry.tracer.spans();
    let shed_root = spans
        .iter()
        .filter(|s| s.name == "request")
        .max_by_key(|s| s.trace)
        .expect("shed root");
    assert!(shed_root.trace > root.trace, "the shed is a fresh trace");
    let shed_trace = spans_of(&spans, shed_root.trace);
    assert_eq!(shed_trace.len(), 2, "a shed trace is root + admission: {shed_trace:?}");
    assert_tree_closed(&shed_trace, "shed request");
    let verdict = find(&shed_trace, "admission").pop().expect("shed admission span");
    assert!(has_arg(verdict, "verdict", "inflight"), "args: {:?}", verdict.args);
}

#[test]
fn sharded_span_tree_covers_every_leg_within_the_request_window() {
    let fleet = fleet(Some(3));
    let a = matrix(11, 18);
    fleet.register("big", a.clone()).unwrap();
    let shard_count = fleet.shard_count("big").unwrap();
    assert!(shard_count >= 2, "a 324-row stencil must split");
    let telemetry = fleet.telemetry();
    telemetry.tracer.set_sample_every(1);

    let x = random_vector(a.ncols, 23);
    let t0 = Instant::now();
    let submission = fleet.submit("big", x).expect("submit");
    submission.recv().expect("sharded fleet must answer");
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;

    let spans = telemetry.tracer.spans();
    let all: Vec<_> = spans.iter().collect();
    let root = find(&all, "request").pop().expect("root span");
    let trace = spans_of(&spans, root.trace);
    assert_tree_closed(&trace, "sharded request");

    let shards = find(&trace, "shard");
    assert_eq!(shards.len(), shard_count, "one shard span per fan-out leg");
    let batches = find(&trace, "batch");
    let kernels = find(&trace, "kernel");
    assert_eq!(batches.len(), shard_count, "each leg records its batch window");
    assert_eq!(kernels.len(), shard_count, "each leg records its kernel");

    // Generous slack for f64 µs arithmetic and scheduler jitter; the
    // ordering being asserted (root opens first, closes last, and never
    // exceeds what the client observed) is structural, not statistical.
    const SLACK_US: f64 = 200.0;
    assert!(
        root.dur_us <= wall_us + SLACK_US,
        "root span ({} µs) cannot exceed the client-observed latency ({wall_us} µs)",
        root.dur_us
    );
    let root_end = root.start_us + root.dur_us;
    for leg in &shards {
        assert_eq!(leg.parent, Some(root.span));
        assert!(
            leg.start_us + 1.0 >= root.start_us,
            "shard leg starts ({} µs) before its root ({} µs)",
            leg.start_us,
            root.start_us
        );
        assert!(
            leg.start_us + leg.dur_us <= root_end + SLACK_US,
            "shard leg ends after its root closed"
        );
    }
    // The slowest leg accounts for (almost all of) the root's duration:
    // legs run concurrently, so the request is as slow as its slowest
    // shard, not the sum.
    let slowest_end =
        shards.iter().map(|s| s.start_us + s.dur_us).fold(0.0f64, f64::max);
    assert!(
        root_end + SLACK_US >= slowest_end,
        "root ({root_end} µs) must cover the slowest leg ({slowest_end} µs)"
    );
    fleet.shutdown();
}

#[test]
fn sampler_hit_rate_is_exact_and_rate_zero_records_nothing() {
    let fleet = fleet(None);
    let a = matrix(13, 12);
    fleet.register("t", a.clone()).unwrap();
    let telemetry = fleet.telemetry();

    // 1-in-4 over 40 sequential requests: the counter-based sampler is
    // exact, not probabilistic.
    telemetry.tracer.set_sample_every(4);
    for i in 0..40 {
        fleet.call("t", random_vector(a.ncols, 300 + i)).expect("serve");
    }
    let stats = telemetry.tracer.stats();
    assert_eq!(stats.sampled, 10, "1-in-4 over 40 requests");
    let roots = telemetry
        .tracer
        .spans()
        .into_iter()
        .filter(|s| s.name == "request")
        .count();
    assert_eq!(roots, 10);

    // Rate 0 turns tracing off entirely: no sampling, no spans.
    telemetry.tracer.set_sample_every(0);
    assert!(!telemetry.tracer.enabled());
    let before = telemetry.tracer.stats();
    for i in 0..20 {
        fleet.call("t", random_vector(a.ncols, 400 + i)).expect("serve");
    }
    assert_eq!(telemetry.tracer.stats(), before, "rate 0 must record nothing");
    fleet.shutdown();
}

#[test]
fn chrome_export_round_trips_through_the_json_parser() {
    let fleet = fleet(Some(2));
    let a = matrix(17, 16);
    fleet.register("t", a.clone()).unwrap();
    let telemetry = fleet.telemetry();
    telemetry.tracer.set_sample_every(1);
    fleet.submit("t", random_vector(a.ncols, 41)).unwrap().recv().unwrap();

    let doc = telemetry.tracer.chrome_trace().to_pretty();
    let parsed = Json::parse(&doc).expect("chrome export must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"), "complete events only");
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
        assert!(e.get("args").and_then(|a| a.get("trace")).is_some());
    }
    // The causal tree survives the export: a shard event's parent is the
    // request event's span id.
    let span_of = |e: &Json| e.get("args").and_then(|a| a.get("span")).and_then(Json::as_f64);
    let request = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("request"))
        .expect("request event");
    let shard = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("shard"))
        .expect("shard event");
    assert_eq!(
        shard.get("args").and_then(|a| a.get("parent")).and_then(Json::as_f64),
        span_of(request),
        "shard's exported parent id is the request's span id"
    );

    // write_chrome produces the same document on disk.
    let path = std::env::temp_dir().join(format!("phi_trace_props_{}.json", std::process::id()));
    let path_str = path.to_str().expect("utf-8 temp path");
    telemetry.tracer.write_chrome(path_str).expect("write trace file");
    let on_disk = std::fs::read_to_string(&path).expect("read trace file back");
    let reparsed = Json::parse(&on_disk).expect("trace file must parse");
    assert_eq!(
        reparsed.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len),
        Some(events.len())
    );
    let _ = std::fs::remove_file(&path);
    fleet.shutdown();
}
