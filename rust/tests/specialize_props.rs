//! Edge-shape and property tests for the const-generic micro-kernel
//! registry (`kernels::specialize`): every registry variant must agree
//! with the generic loops on shapes chosen to stress remainders (block
//! shapes that do not divide the matrix dims, k values off the k-block
//! grid, empty rows), and the prepare paths must never bind a variant
//! whose baked-in shape disagrees with the payload.

use phi_spmv::kernels::op::{ExecCtx, SpmvOp};
use phi_spmv::kernels::specialize::{
    self, KernelFn, BCSR_SHAPES, CSR_UNROLLS, SELL_CHUNKS, SPMM_KBLOCKS,
};
use phi_spmv::kernels::{IsaLevel, Workload};
use phi_spmv::sched::Policy;
use phi_spmv::sparse::{Bcsr, Coo, Csr, Sell};
use phi_spmv::tuner::{prepare_spec, Format};

/// Batch widths stressed against the CSR SpMM k-block kernels: 1 (the
/// degenerate panel), 4 (a grid point), and 17 (prime, off every
/// advertised block width — the remainder loop must carry 1 column).
const EDGE_KS: &[usize] = &[1, 4, 17];

fn assert_close(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        assert!(
            (u - v).abs() < 1e-9 * (1.0 + v.abs()),
            "{what}: row-slot {i}: {u} vs {v}"
        );
    }
}

/// Deterministic test matrix: `m × n` with a band plus scattered fill,
/// rows divisible by 7 left completely empty. The dims are picked by
/// callers to *not* divide the block shapes under test, so every padded
/// tail path runs.
fn edge_matrix(m: usize, n: usize) -> Csr {
    let mut coo = Coo::new(m, n);
    let mut state = 0x2545_f491_4f6c_dd1du64;
    for i in 0..m {
        if i % 7 == 0 {
            continue; // empty row: rptrs[i] == rptrs[i+1]
        }
        for d in 0..5usize {
            let j = (i + d * 3) % n;
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = ((state >> 33) as f64) / (1u64 << 31) as f64 - 1.0;
            coo.push(i, j, v);
        }
        // One far off-band entry to defeat purely banded layouts.
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        coo.push(i, (state as usize) % n, 0.25);
    }
    coo.to_csr()
}

fn dense_vector(n: usize, seed: u64) -> Vec<f64> {
    let mut state = seed | 1;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64) / (1u64 << 31) as f64 - 1.0
        })
        .collect()
}

/// Every registry variant — portable *and* AVX2, regardless of which one
/// `resolve` would pick on this host — against the generic oracle, on a
/// matrix whose dims (37 × 41) divide none of the advertised block
/// shapes. The AVX2 entry points are safe fns that re-check host support
/// on entry, so calling them directly is valid everywhere.
#[test]
fn every_registry_variant_matches_the_generic_oracle() {
    let a = edge_matrix(37, 41);
    let x = dense_vector(a.ncols, 11);
    let want_spmv = a.spmv(&x);
    let mut exercised = 0usize;
    for kern in specialize::registry() {
        match kern.kind {
            KernelFn::CsrSpmv(f) => {
                let mut y = vec![f64::NAN; a.nrows]; // NaN canary: full overwrite required
                f(&a, &x, &mut y, 0..a.nrows);
                assert_close(&y, &want_spmv, kern.name);
            }
            KernelFn::CsrSpmm(f) => {
                for &k in EDGE_KS {
                    let xs = dense_vector(a.ncols * k, 13 + k as u64);
                    let want = a.spmm(&xs, k);
                    let mut y = vec![f64::NAN; a.nrows * k];
                    f(&a, &xs, &mut y, k, 0..a.nrows);
                    assert_close(&y, &want, &format!("{} k={k}", kern.name));
                }
            }
            KernelFn::BcsrSpmv(f) => {
                let b = Bcsr::from_csr(&a, kern.shape.0, kern.shape.1);
                assert!(
                    b.nrows % b.r != 0 || b.ncols % b.c != 0,
                    "edge dims must exercise the partial tail block for {}",
                    kern.name
                );
                let mut y = vec![f64::NAN; b.nrows];
                f(&b, &x, &mut y, 0..b.nbrows());
                assert_close(&y, &want_spmv, kern.name);
            }
            KernelFn::SellSpmv(f) => {
                let s = Sell::from_csr(&a, kern.shape.0, 64);
                assert!(
                    s.nrows % s.chunk != 0,
                    "edge dims must leave a padded final chunk for {}",
                    kern.name
                );
                let mut y = vec![f64::NAN; s.nrows];
                f(&s, &x, y.as_mut_ptr(), 0..s.nchunks());
                assert_close(&y, &want_spmv, kern.name);
            }
        }
        exercised += 1;
    }
    assert_eq!(
        exercised,
        specialize::registry().len(),
        "every advertised variant must have been exercised"
    );
}

/// The registry's own completeness invariants: unique names, a portable
/// entry behind every advertised shape (AVX2 must never be the only
/// implementation — the portable twin is the oracle *and* the non-x86
/// fallback), and the advertised shape lists fully covered.
#[test]
fn registry_is_complete_and_portably_backed() {
    let reg = specialize::registry();
    let mut names: Vec<&str> = reg.iter().map(|k| k.name).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), reg.len(), "variant names must be unique");

    for &(r, c) in BCSR_SHAPES {
        assert!(
            specialize::covers("bcsr", (r, c), IsaLevel::Portable),
            "bcsr {r}x{c} must have a portable kernel"
        );
    }
    for &c in SELL_CHUNKS {
        assert!(
            specialize::covers("sell", (c, 0), IsaLevel::Portable),
            "sell-{c} must have a portable kernel"
        );
    }
    for &u in CSR_UNROLLS {
        assert!(
            specialize::covers("csr", (u, 0), IsaLevel::Portable),
            "csr unroll {u} must have a portable kernel"
        );
    }
    for &kb in SPMM_KBLOCKS {
        assert!(
            specialize::resolve("csr", (kb, 0), true, IsaLevel::Portable).is_some(),
            "csr k-block {kb} must have a portable SpMM kernel"
        );
    }
    // Every non-portable entry has a portable twin at the same
    // (family, shape, kind) — the degradation target always exists.
    for kern in reg.iter().filter(|k| k.isa != IsaLevel::Portable) {
        assert!(
            reg.iter().any(|p| {
                p.isa == IsaLevel::Portable
                    && p.family == kern.family
                    && p.shape == kern.shape
                    && matches!(
                        (&p.kind, &kern.kind),
                        (KernelFn::CsrSpmv(_), KernelFn::CsrSpmv(_))
                            | (KernelFn::CsrSpmm(_), KernelFn::CsrSpmm(_))
                            | (KernelFn::BcsrSpmv(_), KernelFn::BcsrSpmv(_))
                            | (KernelFn::SellSpmv(_), KernelFn::SellSpmv(_))
                    )
            }),
            "{} has no portable twin",
            kern.name
        );
    }
}

/// `prepare_spec` must bind a variant whose baked-in shape matches the
/// payload it will multiply — and must return `None` (never a
/// mismatched kernel) for every shape the registry does not advertise.
#[test]
fn prepare_never_binds_a_mismatched_variant() {
    let a = edge_matrix(53, 47);
    let x = dense_vector(a.ncols, 29);
    let want = a.spmv(&x);
    let isa = IsaLevel::detect();
    let ctx = ExecCtx::serial();

    // BCSR: covered shapes bind `bcsr{r}x{c}_*`; everything else is None.
    for r in 1..=9usize {
        for c in 1..=9usize {
            let format = Format::Bcsr { r, c };
            let covered = specialize::covers("bcsr", (r, c), isa);
            match prepare_spec(&a, format, 1) {
                Some(op) => {
                    assert!(covered, "prepare_spec bound bcsr {r}x{c} without coverage");
                    let name = op.variant_name().expect("specialized payloads name themselves");
                    assert!(
                        name.starts_with(&format!("bcsr{r}x{c}_")),
                        "bcsr {r}x{c} bound {name}"
                    );
                    let mut y = vec![0.0; a.nrows];
                    op.spmv_into(&x, &mut y, &ctx);
                    assert_close(&y, &want, name);
                }
                None => assert!(!covered, "covered bcsr {r}x{c} must prepare"),
            }
        }
    }

    // SELL: same contract over chunk heights.
    for chunk in [2usize, 4, 6, 8, 12, 16, 32] {
        let format = Format::Sell { c: chunk, sigma: 64 };
        let covered = specialize::covers("sell", (chunk, 0), isa);
        match prepare_spec(&a, format, 1) {
            Some(op) => {
                assert!(covered, "prepare_spec bound sell-{chunk} without coverage");
                let name = op.variant_name().unwrap();
                assert!(name.starts_with(&format!("sell{chunk}_")), "sell-{chunk} bound {name}");
                let mut y = vec![0.0; a.nrows];
                op.spmv_into(&x, &mut y, &ctx);
                assert_close(&y, &want, name);
            }
            None => assert!(!covered, "covered sell-{chunk} must prepare"),
        }
    }

    // CSR: the unroll follows the mean row length, the k-block the batch
    // width; both are recorded in the variant name.
    let per_row = a.nnz() as f64 / a.nrows.max(1) as f64;
    let unroll = specialize::csr_unroll_for(per_row);
    for &k in EDGE_KS {
        let Some(op) = prepare_spec(&a, Format::Csr, k) else {
            panic!("CSR is always covered at any ISA");
        };
        let name = op.variant_name().unwrap();
        if k > 1 {
            let kb = specialize::spmm_kblock_for(k);
            assert!(
                name.starts_with(&format!("csr_mm{kb}_")),
                "csr k={k} bound {name}, expected k-block {kb}"
            );
            let xs = dense_vector(a.ncols * k, 31 + k as u64);
            let mut y = vec![0.0; a.nrows * k];
            op.apply(Workload::Spmm { k }, &xs, &mut y, &ctx);
            assert_close(&y, &a.spmm(&xs, k), name);
        } else {
            assert!(
                name.starts_with(&format!("csr_u{unroll}_")),
                "csr spmv bound {name}, expected unroll {unroll}"
            );
            let mut y = vec![0.0; a.nrows];
            op.apply(Workload::Spmv, &x, &mut y, &ctx);
            assert_close(&y, &want, name);
        }
    }

    // Formats outside the registry's families never specialize.
    assert!(prepare_spec(&a, Format::Ell, 1).is_none());
    assert!(prepare_spec(&a, Format::Hyb { width: 4 }, 1).is_none());
}

/// Specialized payloads must stay correct under the threaded scheduler,
/// not just the serial path — row/chunk partitioning interacts with the
/// baked-in shapes (a partition boundary mid-block must not double- or
/// zero-write).
#[test]
fn specialized_payloads_survive_threaded_partitioning() {
    let a = edge_matrix(67, 59);
    let x = dense_vector(a.ncols, 41);
    let want = a.spmv(&x);
    for format in [
        Format::Csr,
        Format::Bcsr { r: 4, c: 4 },
        Format::Bcsr { r: 8, c: 1 },
        Format::Sell { c: 8, sigma: 64 },
    ] {
        let Some(op) = prepare_spec(&a, format, 1) else {
            continue; // shape uncovered at this ISA: nothing to stress
        };
        for threads in [2usize, 3, 5] {
            let ctx = ExecCtx::pooled(threads, Policy::Dynamic(4));
            let mut y = vec![0.0; a.nrows];
            op.spmv_into(&x, &mut y, &ctx);
            assert_close(&y, &want, &format!("{format} under {threads} threads"));
        }
    }
}

/// The tuner's fingerprint-nearest-neighbor priors: a second,
/// structurally near-identical matrix must be searched with strictly
/// fewer trials than the first (the prior seeds and halves its
/// candidate list).
#[test]
fn priors_shrink_the_second_search() {
    use phi_spmv::sparse::gen::stencil::stencil_2d;
    use phi_spmv::telemetry::{names, Telemetry};
    use phi_spmv::tuner::Tuner;

    let t = Telemetry::new();
    let mut tuner = Tuner::quick().with_telemetry(t.clone());
    let a = stencil_2d(32, 31);
    let b = stencil_2d(32, 32);
    tuner.tune("a", &a).unwrap();
    let first = t.metrics.counter(names::TUNER_TRIALS).get();
    tuner.tune("b", &b).unwrap();
    let second = t.metrics.counter(names::TUNER_TRIALS).get() - first;
    assert_eq!(tuner.cache.misses, 2, "distinct fingerprints must both search");
    assert!(
        second < first,
        "prior-seeded search must issue strictly fewer trials ({second} vs {first})"
    );
}
