//! Property-based tests over the library's core invariants.
//!
//! Uses the in-tree property driver (`util::prop`, the offline stand-in
//! for proptest — see Cargo.toml). Case count: env `PHI_PROP_CASES`.

use phi_spmv::kernels::{spmm_parallel, spmv_parallel};
use phi_spmv::sched::{Policy, StaticAssignment};
use phi_spmv::sparse::bcsr::PAPER_BLOCK_CONFIGS;
use phi_spmv::sparse::ordering::{apply_symmetric_permutation, invert_permutation, is_permutation, rcm};
use phi_spmv::sparse::stats::{matrix_bandwidth, row_ucld, ucld};
use phi_spmv::sparse::{Bcsr, Ell};
use phi_spmv::util::prop::{arb, check};

fn close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (u, v)) in a.iter().zip(b).enumerate() {
        if (u - v).abs() > tol * (1.0 + v.abs()) {
            return Err(format!("idx {i}: {u} vs {v}"));
        }
    }
    Ok(())
}

#[test]
fn prop_format_roundtrips_preserve_matrix() {
    check(
        "format-roundtrips",
        |rng| arb::csr(rng, 40, 10),
        |a| {
            if a.to_coo().to_csr() != *a {
                return Err("coo roundtrip".into());
            }
            if a.to_csc().to_csr() != *a {
                return Err("csc roundtrip".into());
            }
            if a.transpose().transpose() != *a {
                return Err("transpose involution".into());
            }
            if Ell::from_csr(a, 0).to_csr() != *a {
                return Err("ell roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bcsr_roundtrip_and_spmv_all_configs() {
    check(
        "bcsr-roundtrip-spmv",
        |rng| {
            let a = arb::csr(rng, 30, 6);
            let x = arb::vector(rng, a.ncols);
            (a, x)
        },
        |(a, x)| {
            let want = a.spmv(x);
            for (r, c) in PAPER_BLOCK_CONFIGS {
                let b = Bcsr::from_csr(a, r, c);
                if b.to_csr() != *a {
                    return Err(format!("bcsr {r}x{c} roundtrip"));
                }
                close(&b.spmv(x), &want, 1e-10).map_err(|e| format!("{r}x{c}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_spmv_matches_serial_any_policy() {
    check(
        "parallel-spmv",
        |rng| {
            let a = arb::csr(rng, 600, 12);
            let x = arb::vector(rng, a.ncols);
            let policy = match rng.usize_below(4) {
                0 => Policy::StaticBlock,
                1 => Policy::StaticChunk(1 + rng.usize_below(70)),
                2 => Policy::Dynamic(1 + rng.usize_below(70)),
                _ => Policy::Guided(1 + rng.usize_below(30)),
            };
            let threads = 1 + rng.usize_below(7);
            (a, x, policy, threads)
        },
        |(a, x, policy, threads)| {
            close(&spmv_parallel(a, x, *threads, *policy), &a.spmv(x), 1e-10)
        },
    );
}

#[test]
fn prop_spmv_linearity() {
    check(
        "spmv-linearity",
        |rng| {
            let a = arb::csr(rng, 50, 8);
            let x = arb::vector(rng, a.ncols);
            let z = arb::vector(rng, a.ncols);
            (a, x, z)
        },
        |(a, x, z)| {
            let combo: Vec<f64> = x.iter().zip(z).map(|(u, v)| 2.0 * u - 0.5 * v).collect();
            let lhs = a.spmv(&combo);
            let ax = a.spmv(x);
            let az = a.spmv(z);
            let rhs: Vec<f64> = ax.iter().zip(&az).map(|(u, v)| 2.0 * u - 0.5 * v).collect();
            close(&lhs, &rhs, 1e-9)
        },
    );
}

#[test]
fn prop_spmm_k_columns_equal_k_spmvs() {
    check(
        "spmm-columns",
        |rng| {
            let a = arb::csr(rng, 300, 8);
            let k = 1 + rng.usize_below(6);
            let x = arb::vector(rng, a.ncols * k);
            (a, x, k)
        },
        |(a, x, k)| {
            let y = spmm_parallel(a, x, *k, 4, Policy::Dynamic(16));
            for col in 0..*k {
                let xc: Vec<f64> = (0..a.ncols).map(|i| x[i * k + col]).collect();
                let want = a.spmv(&xc);
                let got: Vec<f64> = (0..a.nrows).map(|i| y[i * k + col]).collect();
                close(&got, &want, 1e-10).map_err(|e| format!("col {col}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_covers_exactly_once() {
    check(
        "scheduler-coverage",
        |rng| {
            let n = rng.usize_below(5000);
            let threads = 1 + rng.usize_below(64);
            let policy = match rng.usize_below(4) {
                0 => Policy::StaticBlock,
                1 => Policy::StaticChunk(1 + rng.usize_below(100)),
                2 => Policy::Dynamic(1 + rng.usize_below(100)),
                _ => Policy::Guided(1 + rng.usize_below(50)),
            };
            (n, threads, policy)
        },
        |(n, threads, policy)| {
            let a = StaticAssignment::build(*policy, *n, *threads);
            if !a.covers_exactly(*n) {
                return Err(format!("{policy} does not cover 0..{n} with {threads} threads"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rcm_is_permutation_and_preserves_spmv() {
    check(
        "rcm-permutation",
        |rng| {
            let a = arb::square_csr(rng, 60, 5);
            let x = arb::vector(rng, a.ncols);
            (a, x)
        },
        |(a, x)| {
            let perm = rcm(a);
            if !is_permutation(&perm) {
                return Err("not a permutation".into());
            }
            let inv = invert_permutation(&perm);
            if invert_permutation(&inv) != perm {
                return Err("inverse not involutive".into());
            }
            let b = apply_symmetric_permutation(a, &perm);
            if b.nnz() != a.nnz() {
                return Err("nnz changed".into());
            }
            // (PAPᵀ)(Px) == P(Ax)
            let px: Vec<f64> = perm.iter().map(|&p| x[p as usize]).collect();
            let by = b.spmv(&px);
            let ay = a.spmv(x);
            let pay: Vec<f64> = perm.iter().map(|&p| ay[p as usize]).collect();
            close(&by, &pay, 1e-9)
        },
    );
}

#[test]
fn prop_rcm_never_worsens_bandwidth_much_on_banded() {
    // RCM on an already-banded matrix must keep bandwidth within a small
    // factor (it's the structure RCM is designed for).
    check(
        "rcm-banded",
        |rng| {
            use phi_spmv::sparse::gen::banded::{banded_runs, BandedSpec};
            banded_runs(&BandedSpec {
                n: 200 + rng.usize_below(300),
                mean_row: 6.0,
                run: 1 + rng.usize_below(4),
                locality: 0.03,
                seed: rng.next_u64(),
            })
        },
        |a| {
            let before = matrix_bandwidth(a);
            let b = apply_symmetric_permutation(a, &rcm(a));
            let after = matrix_bandwidth(&b);
            if after > before * 2 + 8 {
                return Err(format!("bandwidth {before} → {after}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ucld_bounds() {
    check(
        "ucld-bounds",
        |rng| arb::csr(rng, 60, 12),
        |a| {
            let u = ucld(a);
            if !(0.125..=1.0 + 1e-12).contains(&u) {
                return Err(format!("ucld {u} out of [1/8, 1]"));
            }
            for i in 0..a.nrows {
                let r = row_ucld(a.row_cids(i));
                if !(0.125..=1.0 + 1e-12).contains(&r) {
                    return Err(format!("row {i} ucld {r}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulated_time_monotone_in_work() {
    use phi_spmv::arch::mem::StoreFlavour;
    use phi_spmv::arch::phi::{PhiMachine, WorkProfile};
    check(
        "model-monotone",
        |rng| {
            let base = WorkProfile {
                instructions: 1e6 + rng.f64() * 1e9,
                pairable: rng.f64() * 0.5,
                stream_read_bytes: 1e6 + rng.f64() * 1e9,
                stream_prefetched: rng.bool(0.5),
                random_read_lines: rng.f64() * 1e6,
                l2_lines: rng.f64() * 1e7,
                write_bytes: rng.f64() * 1e8,
                store: StoreFlavour::Ordered,
                flops: 1e6,
                app_bytes: 1e6,
                imbalance: 1.0 + rng.f64() * 0.5,
            };
            let cores = 1 + rng.usize_below(61);
            let threads = 1 + rng.usize_below(4);
            (base, cores, threads)
        },
        |(w, cores, threads)| {
            let m = PhiMachine::se10p();
            let t0 = m.estimate(*cores, *threads, w).time_s;
            // Doubling every work term must not reduce time.
            let mut w2 = *w;
            w2.instructions *= 2.0;
            w2.stream_read_bytes *= 2.0;
            w2.random_read_lines *= 2.0;
            w2.l2_lines *= 2.0;
            w2.write_bytes *= 2.0;
            let t2 = m.estimate(*cores, *threads, &w2).time_s;
            if t2 + 1e-15 < t0 {
                return Err(format!("time decreased: {t0} → {t2}"));
            }
            // And time must be positive and finite.
            if !(t0.is_finite() && t0 > 0.0) {
                return Err(format!("bad time {t0}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ucld_permutation_invariant_under_identity() {
    check(
        "ucld-identity-perm",
        |rng| arb::square_csr(rng, 50, 6),
        |a| {
            let perm: Vec<u32> = (0..a.nrows as u32).collect();
            let b = apply_symmetric_permutation(a, &perm);
            if b != *a {
                return Err("identity permutation changed the matrix".into());
            }
            if (ucld(&b) - ucld(a)).abs() > 1e-12 {
                return Err("identity permutation changed UCLD".into());
            }
            Ok(())
        },
    );
}
