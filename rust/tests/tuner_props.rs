//! Property tests for the auto-tuner: whatever configuration the search
//! lands on, the numerics must match the serial CSR oracle, and the
//! persistent cache must round-trip deterministically.

use phi_spmv::sched::Policy;
use phi_spmv::sparse::ordering::apply_symmetric_permutation;
use phi_spmv::sparse::{Coo, MatrixStats};
use phi_spmv::tuner::space::{enumerate_for, SpaceConfig};
use phi_spmv::tuner::{
    Format, Ordering, Prepared, TunedConfig, Tuner, TunerConfig, TuningCache, Workload,
};
use phi_spmv::util::prop::{arb, check};

fn assert_close(got: &[f64], want: &[f64]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("length {} vs {}", got.len(), want.len()));
    }
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        if (u - v).abs() > 1e-9 * (1.0 + v.abs()) {
            return Err(format!("idx {i}: {u} vs {v}"));
        }
    }
    Ok(())
}

#[test]
fn tuned_config_always_matches_serial_oracle() {
    check(
        "tuner-oracle",
        |rng| {
            let a = arb::csr(rng, 120, 10);
            let x = arb::vector(rng, a.ncols);
            (a, x)
        },
        |(a, x)| {
            let mut tuner = Tuner::quick();
            let y = tuner.tune_and_run("prop", a, x).map_err(|e| e.to_string())?;
            assert_close(&y, &a.spmv(x))
        },
    );
}

#[test]
fn every_surviving_candidate_matches_serial_oracle() {
    // Stronger than the tuned pick: whatever the pruner lets through —
    // under either workload — must be numerically safe, so the trialer
    // can never "win" with a wrong kernel.
    check(
        "space-oracle",
        |rng| {
            let a = arb::square_csr(rng, 80, 8);
            let k = 1 + rng.usize_below(5);
            let x = arb::vector(rng, a.ncols);
            let xk = arb::vector(rng, a.ncols * k);
            (a, k, x, xk)
        },
        |(a, k, x, xk)| {
            let stats = MatrixStats::compute("prop", a);
            let spmv_space = enumerate_for(a, &stats, &SpaceConfig::quick(), Workload::Spmv);
            if spmv_space.candidates.is_empty() {
                return Err("space must never be empty (CSR is always in)".to_string());
            }
            let want = a.spmv(x);
            for cand in &spmv_space.candidates {
                let y = Prepared::new(a, *cand).spmv(x);
                assert_close(&y, &want).map_err(|e| format!("{cand}: {e}"))?;
            }
            let workload = Workload::Spmm { k: *k };
            let spmm_space = enumerate_for(a, &stats, &SpaceConfig::quick(), workload);
            if spmm_space.candidates.is_empty() {
                return Err("spmm space must never be empty (CSR is always in)".to_string());
            }
            let want_k = a.spmm(xk, *k);
            for cand in &spmm_space.candidates {
                let y = Prepared::new(a, *cand).spmm(xk, *k);
                assert_close(&y, &want_k).map_err(|e| format!("{cand} k={k}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn spmm_decisions_never_shadow_spmv_decisions() {
    check(
        "workload-keys-disjoint",
        |rng| {
            let a = arb::csr(rng, 90, 8);
            let k = 2 + rng.usize_below(15);
            (a, k)
        },
        |(a, k)| {
            let mut tuner = Tuner::quick();
            let spmv = tuner.tune("m", a).map_err(|e| e.to_string())?;
            let spmm = tuner
                .tune_workload("m", a, Workload::Spmm { k: *k })
                .map_err(|e| e.to_string())?;
            if spmv.workload != Workload::Spmv {
                return Err(format!("spmv decision tagged {}", spmv.workload));
            }
            if spmm.workload != (Workload::Spmm { k: *k }) {
                return Err(format!("spmm decision tagged {}", spmm.workload));
            }
            if tuner.cache.misses != 2 {
                return Err(format!("expected 2 misses, got {}", tuner.cache.misses));
            }
            // Re-asking returns both verbatim from the cache.
            let spmv2 = tuner.tune("m", a).map_err(|e| e.to_string())?;
            let spmm2 = tuner
                .tune_workload("m", a, Workload::Spmm { k: *k })
                .map_err(|e| e.to_string())?;
            if spmv2 != spmv || spmm2 != spmm {
                return Err("cached decisions changed".to_string());
            }
            if tuner.cache.hits != 2 {
                return Err(format!("expected 2 hits, got {}", tuner.cache.hits));
            }
            Ok(())
        },
    );
}

#[test]
fn scrambled_band_tunes_to_rcm_and_permuted_op_matches_the_oracle() {
    // The §4.4 property: a banded matrix scrambled by a random symmetric
    // permutation must tune to `Ordering::Rcm` under the deterministic
    // model-only path (the post-reorder analysis sees the recovered
    // locality), and the decision's PermutedOp must be transparent — its
    // output matches the natural-order oracle for both workloads.
    check(
        "rcm-axis",
        |rng| {
            // A dense band: each row touches a contiguous window around
            // the diagonal.
            let n = 300 + rng.usize_below(300);
            let half = 4 + rng.usize_below(4);
            let mut coo = Coo::new(n, n);
            for i in 0..n {
                coo.push(i, i, 4.0);
                for j in i.saturating_sub(half)..(i + half + 1).min(n) {
                    if j != i && rng.bool(0.85) {
                        coo.push(i, j, rng.f64_range(-1.0, 1.0));
                    }
                }
            }
            let a = coo.to_csr();
            let mut shuffle: Vec<u32> = (0..n as u32).collect();
            for i in (1..n).rev() {
                let j = rng.usize_below(i + 1);
                shuffle.swap(i, j);
            }
            let scrambled = apply_symmetric_permutation(&a, &shuffle);
            let k = 2 + rng.usize_below(6);
            let x = arb::vector(rng, n);
            let xk = arb::vector(rng, n * k);
            (scrambled, k, x, xk)
        },
        |(a, k, x, xk)| {
            let mut tuner = Tuner::new(TunerConfig::model_only(), TuningCache::in_memory());
            let spmv = tuner.tune("scrambled-band", a).map_err(|e| e.to_string())?;
            if spmv.ordering != Ordering::Rcm {
                return Err(format!("spmv decision kept natural order: {spmv}"));
            }
            let workload = Workload::Spmm { k: *k };
            let spmm = tuner
                .tune_workload("scrambled-band", a, workload)
                .map_err(|e| e.to_string())?;
            if spmm.ordering != Ordering::Rcm {
                return Err(format!("spmm decision kept natural order: {spmm}"));
            }
            // Natural-order semantics all the way through the wrapper.
            assert_close(&Prepared::new(a, spmv.candidate()).spmv(x), &a.spmv(x))
                .map_err(|e| format!("spmv via {spmv}: {e}"))?;
            assert_close(&Prepared::new(a, spmm.candidate()).spmm(xk, *k), &a.spmm(xk, *k))
                .map_err(|e| format!("spmm via {spmm}: {e}"))?;
            Ok(())
        },
    );
}

#[test]
fn already_banded_matrix_keeps_natural_order() {
    // The prune half of the acceptance: a matrix whose nonzeros already
    // hug the diagonal never searches (and so never selects) RCM.
    let a = phi_spmv::sparse::gen::stencil::stencil_2d(25, 24);
    for config in [TunerConfig::model_only(), TunerConfig::quick()] {
        let mut tuner = Tuner::new(config, TuningCache::in_memory());
        let spmv = tuner.tune("stencil", &a).unwrap();
        assert_eq!(spmv.ordering, Ordering::Natural, "{spmv}");
        let spmm = tuner.tune_workload("stencil", &a, Workload::Spmm { k: 8 }).unwrap();
        assert_eq!(spmm.ordering, Ordering::Natural, "{spmm}");
    }
}

#[test]
fn cached_decision_is_returned_verbatim() {
    check(
        "cache-stability",
        |rng| arb::csr(rng, 100, 8),
        |a| {
            let mut tuner = Tuner::quick();
            let first = tuner.tune("m", a).map_err(|e| e.to_string())?;
            let second = tuner.tune("m", a).map_err(|e| e.to_string())?;
            if first != second {
                return Err(format!("decision changed: {first} vs {second}"));
            }
            if tuner.cache.hits != 1 {
                return Err(format!("expected exactly one hit, got {}", tuner.cache.hits));
            }
            Ok(())
        },
    );
}

#[test]
fn tuning_cache_roundtrips_deterministically_through_json() {
    check(
        "cache-json-roundtrip",
        |rng| {
            // A random cache: random keys mapped to random-but-valid configs.
            let n = 1 + rng.usize_below(8);
            let mut cache = TuningCache::in_memory();
            for _ in 0..n {
                let workload = if rng.bool(0.5) {
                    Workload::Spmv
                } else {
                    Workload::Spmm { k: 1 + rng.usize_below(32) }
                };
                let format = match rng.usize_below(5) {
                    0 => Format::Csr,
                    1 => Format::Ell,
                    2 => Format::Bcsr { r: 1 + rng.usize_below(8), c: 1 + rng.usize_below(8) },
                    3 => Format::Sell {
                        c: 1 + rng.usize_below(32),
                        sigma: 1 + rng.usize_below(1024),
                    },
                    _ => Format::Hyb { width: 1 + rng.usize_below(32) },
                };
                let policy = match rng.usize_below(4) {
                    0 => Policy::StaticBlock,
                    1 => Policy::StaticChunk(1 + rng.usize_below(256)),
                    2 => Policy::Dynamic(1 + rng.usize_below(256)),
                    _ => Policy::Guided(1 + rng.usize_below(64)),
                };
                cache.insert(
                    format!("{:016x}", rng.next_u64()),
                    TunedConfig {
                        workload,
                        format,
                        ordering: if rng.bool(0.5) { Ordering::Natural } else { Ordering::Rcm },
                        policy,
                        threads: 1 + rng.usize_below(64),
                        variant: if rng.bool(0.25) {
                            Some(format!("csr_u{}_avx2", 1 << rng.usize_below(3)))
                        } else {
                            None
                        },
                        gflops: (rng.usize_below(10_000) as f64) / 64.0,
                        source: if rng.bool(0.5) { "trial".into() } else { "model".into() },
                        tuned_at: rng.next_u64() % 2_000_000_000,
                    },
                );
            }
            cache
        },
        |cache| {
            let j = cache.to_json();
            let text = j.to_pretty();
            let parsed = phi_spmv::util::json::Json::parse(&text).map_err(|e| e.to_string())?;
            let back = TuningCache::from_json(&parsed).map_err(|e| e.to_string())?;
            if back.len() != cache.len() {
                return Err(format!("entry count {} vs {}", back.len(), cache.len()));
            }
            // Serialize → parse → serialize must be a fixed point.
            let text2 = back.to_json().to_pretty();
            if text != text2 {
                return Err("serialization is not deterministic".to_string());
            }
            Ok(())
        },
    );
}
