//! Property tests for the auto-tuner: whatever configuration the search
//! lands on, the numerics must match the serial CSR oracle, and the
//! persistent cache must round-trip deterministically.

use phi_spmv::sched::Policy;
use phi_spmv::sparse::MatrixStats;
use phi_spmv::tuner::space::{enumerate, SpaceConfig};
use phi_spmv::tuner::{Format, Prepared, TunedConfig, Tuner, TuningCache};
use phi_spmv::util::prop::{arb, check};

fn assert_close(got: &[f64], want: &[f64]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("length {} vs {}", got.len(), want.len()));
    }
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        if (u - v).abs() > 1e-9 * (1.0 + v.abs()) {
            return Err(format!("idx {i}: {u} vs {v}"));
        }
    }
    Ok(())
}

#[test]
fn tuned_config_always_matches_serial_oracle() {
    check(
        "tuner-oracle",
        |rng| {
            let a = arb::csr(rng, 120, 10);
            let x = arb::vector(rng, a.ncols);
            (a, x)
        },
        |(a, x)| {
            let mut tuner = Tuner::quick();
            let y = tuner.tune_and_run("prop", a, x).map_err(|e| e.to_string())?;
            assert_close(&y, &a.spmv(x))
        },
    );
}

#[test]
fn every_surviving_candidate_matches_serial_oracle() {
    // Stronger than the tuned pick: whatever the pruner lets through must
    // be numerically safe, so the trialer can never "win" with a wrong
    // kernel.
    check(
        "space-oracle",
        |rng| {
            let a = arb::square_csr(rng, 80, 8);
            let x = arb::vector(rng, a.ncols);
            (a, x)
        },
        |(a, x)| {
            let stats = MatrixStats::compute("prop", a);
            let space = enumerate(a, &stats, &SpaceConfig::quick());
            if space.candidates.is_empty() {
                return Err("space must never be empty (CSR is always in)".to_string());
            }
            let want = a.spmv(x);
            for cand in &space.candidates {
                let y = Prepared::new(a, *cand).spmv(x);
                assert_close(&y, &want).map_err(|e| format!("{cand}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn cached_decision_is_returned_verbatim() {
    check(
        "cache-stability",
        |rng| arb::csr(rng, 100, 8),
        |a| {
            let mut tuner = Tuner::quick();
            let first = tuner.tune("m", a).map_err(|e| e.to_string())?;
            let second = tuner.tune("m", a).map_err(|e| e.to_string())?;
            if first != second {
                return Err(format!("decision changed: {first} vs {second}"));
            }
            if tuner.cache.hits != 1 {
                return Err(format!("expected exactly one hit, got {}", tuner.cache.hits));
            }
            Ok(())
        },
    );
}

#[test]
fn tuning_cache_roundtrips_deterministically_through_json() {
    check(
        "cache-json-roundtrip",
        |rng| {
            // A random cache: random keys mapped to random-but-valid configs.
            let n = 1 + rng.usize_below(8);
            let mut cache = TuningCache::in_memory();
            for _ in 0..n {
                let format = match rng.usize_below(5) {
                    0 => Format::Csr,
                    1 => Format::Ell,
                    2 => Format::Bcsr { r: 1 + rng.usize_below(8), c: 1 + rng.usize_below(8) },
                    3 => Format::Sell {
                        c: 1 + rng.usize_below(32),
                        sigma: 1 + rng.usize_below(1024),
                    },
                    _ => Format::Hyb { width: 1 + rng.usize_below(32) },
                };
                let policy = match rng.usize_below(4) {
                    0 => Policy::StaticBlock,
                    1 => Policy::StaticChunk(1 + rng.usize_below(256)),
                    2 => Policy::Dynamic(1 + rng.usize_below(256)),
                    _ => Policy::Guided(1 + rng.usize_below(64)),
                };
                cache.insert(
                    format!("{:016x}", rng.next_u64()),
                    TunedConfig {
                        format,
                        policy,
                        threads: 1 + rng.usize_below(64),
                        gflops: (rng.usize_below(10_000) as f64) / 64.0,
                        source: if rng.bool(0.5) { "trial".into() } else { "model".into() },
                    },
                );
            }
            cache
        },
        |cache| {
            let j = cache.to_json();
            let text = j.to_pretty();
            let parsed = phi_spmv::util::json::Json::parse(&text).map_err(|e| e.to_string())?;
            let back = TuningCache::from_json(&parsed).map_err(|e| e.to_string())?;
            if back.len() != cache.len() {
                return Err(format!("entry count {} vs {}", back.len(), cache.len()));
            }
            // Serialize → parse → serialize must be a fixed point.
            let text2 = back.to_json().to_pretty();
            if text != text2 {
                return Err("serialization is not deterministic".to_string());
            }
            Ok(())
        },
    );
}
