//! Sharded serving vs the unsharded oracle, at three levels: pure
//! row-slice assembly (SpMV and SpMM, k ∈ {1, 4, 17}) across
//! stencil/power-law/banded/ragged patterns and shard counts
//! {1, 2, 3, 8}; a hand-seeded `ShardEngine`; and the fleet with
//! sharding forced on — plus shard-plan determinism and the
//! fault-injection story (a dead shard must yield explicit errors,
//! never poison peers, and recover on re-materialization).
//!
//! Case count for the property sweep: env `PHI_PROP_CASES`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use phi_spmv::fleet::shard::{plan_ranges, row_slice, shard_name, ShardConfig, ShardEngine, ShardSeed};
use phi_spmv::fleet::{Fleet, FleetConfig, RetuneConfig};
use phi_spmv::kernels::Workload;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::banded::{banded_runs, BandedSpec};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::Csr;
use phi_spmv::telemetry::Telemetry;
use phi_spmv::tuner::{Format, Ordering, TunedConfig, Tuner, TunerConfig, TuningCache};
use phi_spmv::util::prop::{arb, check};

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 8];
const WIDTHS: [usize; 3] = [1, 4, 17];

fn assert_close(got: &[f64], want: &[f64], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (u, v)) in got.iter().zip(want).enumerate() {
        assert!((u - v).abs() < 1e-9 * (1.0 + v.abs()), "{tag}: idx {i}: {u} vs {v}");
    }
}

/// Slices `a` along `plan`, runs each shard's kernel, and assembles the
/// partial results in row order — the pure (engine-free) form of what
/// `ShardEngine`/`Submission` do.
fn assemble(a: &Csr, plan: &[std::ops::Range<usize>], x: &[f64], k: usize) -> Vec<f64> {
    let mut y = vec![0.0; a.nrows * k];
    for r in plan {
        let sub = row_slice(a, r);
        let part = if k == 1 { sub.spmv(x) } else { sub.spmm(x, k) };
        y[r.start * k..r.end * k].copy_from_slice(&part);
    }
    y
}

fn check_all_plans(a: &Csr, tag: &str) {
    for &k in &WIDTHS {
        let x = random_vector(a.ncols * k, 11 + k as u64);
        let want = if k == 1 { a.spmv(&x) } else { a.spmm(&x, k) };
        for &shards in &SHARD_COUNTS {
            let plan = plan_ranges(a, &ShardConfig { threshold_nnz: 0, shards });
            let got = assemble(a, &plan, &x, k);
            assert_close(&got, &want, &format!("{tag}: {shards} shards, k={k}"));
        }
    }
}

#[test]
fn sharded_assembly_matches_the_oracle_across_pattern_classes() {
    let mut stencil = stencil_2d(14, 14);
    randomize_values(&mut stencil, 3);
    check_all_plans(&stencil, "stencil");

    let pl = powerlaw(&PowerLawSpec {
        n: 400,
        nnz: 4_000,
        row_alpha: 1.7,
        col_alpha: 1.2,
        max_row: 80,
        seed: 5,
    });
    check_all_plans(&pl, "powerlaw");

    let banded =
        banded_runs(&BandedSpec { n: 300, mean_row: 9.0, run: 4, locality: 0.08, seed: 7 });
    check_all_plans(&banded, "banded");
}

#[test]
fn sharded_assembly_matches_the_oracle_on_edge_shapes() {
    // Ragged with empty rows — including an empty first and last row, the
    // shapes most likely to break row-pointer rebasing at a boundary.
    let ragged = Csr::from_parts(
        6,
        5,
        vec![0, 0, 3, 3, 3, 7, 7],
        vec![0, 2, 4, 0, 1, 2, 3],
        vec![1.0, -2.0, 3.0, 0.5, -0.25, 4.0, 8.0],
    )
    .expect("valid ragged CSR");
    check_all_plans(&ragged, "ragged-empty-rows");

    // Fewer rows than requested shards: every shard is a single row and
    // the empty tail ranges must be dropped, not served.
    let tiny = Csr::from_parts(3, 4, vec![0, 2, 2, 5], vec![0, 3, 1, 2, 3], vec![
        1.0, 2.0, 3.0, 4.0, 5.0,
    ])
    .expect("valid tiny CSR");
    let plan = plan_ranges(&tiny, &ShardConfig { threshold_nnz: 0, shards: 8 });
    assert!(plan.len() <= tiny.nrows, "no shard may be empty");
    assert!(plan.iter().all(|r| !r.is_empty()));
    check_all_plans(&tiny, "single-row-shards");
}

#[test]
fn shard_plans_are_deterministic_disjoint_and_covering() {
    check(
        "shard plan determinism & coverage",
        |rng| {
            let a = arb::csr(rng, 60, 6);
            let shards = 1 + rng.usize_below(8);
            (a, shards)
        },
        |(a, shards)| {
            let config = ShardConfig { threshold_nnz: 0, shards: *shards };
            let plan = plan_ranges(a, &config);
            if plan != plan_ranges(a, &config) {
                return Err("same matrix + config must give the same plan".into());
            }
            if plan.first().map(|r| r.start) != Some(0)
                || plan.last().map(|r| r.end) != Some(a.nrows)
            {
                return Err(format!("plan {plan:?} does not span 0..{}", a.nrows));
            }
            for w in plan.windows(2) {
                if w[0].end != w[1].start {
                    return Err(format!("ranges {:?} and {:?} do not tile", w[0], w[1]));
                }
            }
            if plan.len() > 1 && plan.iter().any(|r| r.is_empty()) {
                return Err(format!("multi-shard plan {plan:?} contains an empty range"));
            }
            // Below the threshold the plan must degenerate to one range.
            let off = ShardConfig { threshold_nnz: a.nnz() + 1, shards: *shards };
            if plan_ranges(a, &off).len() != 1 {
                return Err("below-threshold matrices must not shard".into());
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_assembly_property_on_random_ragged_matrices() {
    check(
        "sharded SpMV/SpMM assembly == oracle",
        |rng| {
            let a = arb::csr(rng, 50, 5);
            let shards = SHARD_COUNTS[rng.usize_below(SHARD_COUNTS.len())];
            let k = WIDTHS[rng.usize_below(WIDTHS.len())];
            let x = arb::vector(rng, a.ncols * k);
            (a, shards, k, x)
        },
        |(a, shards, k, x)| {
            let plan = plan_ranges(a, &ShardConfig { threshold_nnz: 0, shards: *shards });
            let want = if *k == 1 { a.spmv(x) } else { a.spmm(x, *k) };
            let got = assemble(a, &plan, x, *k);
            for (i, (u, v)) in got.iter().zip(&want).enumerate() {
                if (u - v).abs() >= 1e-9 * (1.0 + v.abs()) {
                    return Err(format!("row-element {i}: {u} vs oracle {v}"));
                }
            }
            Ok(())
        },
    );
}

fn csr_decision(workload: Workload) -> TunedConfig {
    TunedConfig {
        workload,
        format: Format::Csr,
        ordering: Ordering::Natural,
        policy: Policy::StaticBlock,
        threads: 1,
        variant: None,
        gflops: 1.0,
        source: "model".into(),
        tuned_at: 0,
    }
}

#[test]
fn shard_engine_serves_concurrent_requests_with_hand_built_seeds() {
    let mut a = stencil_2d(16, 16);
    randomize_values(&mut a, 9);
    let a = Arc::new(a);
    let plan = plan_ranges(&a, &ShardConfig { threshold_nnz: 0, shards: 3 });
    assert!(plan.len() >= 2, "a 256-row stencil must split");
    let seeds: Vec<ShardSeed> = plan
        .iter()
        .enumerate()
        .map(|(idx, r)| ShardSeed {
            name: shard_name("hand", idx),
            range: r.clone(),
            a: Arc::new(row_slice(&a, r)),
            spmv: csr_decision(Workload::Spmv),
            spmm: csr_decision(Workload::Spmm { k: 4 }),
        })
        .collect();
    let engine =
        ShardEngine::start(seeds, 4, Duration::from_millis(1), false, Telemetry::new());
    assert_eq!(engine.shards(), plan.len());

    // Many requests in flight at once: batching may fuse any subset on
    // any shard; every response must still be that request's oracle.
    let inputs: Vec<Vec<f64>> = (0..10).map(|i| random_vector(a.ncols, 20 + i)).collect();
    let submissions: Vec<_> =
        inputs.iter().map(|x| engine.submit(x.clone()).expect("submit")).collect();
    for (x, s) in inputs.iter().zip(submissions) {
        let resp = s.recv().expect("healthy shards must answer");
        assert_close(&resp.y, &a.spmv(x), "hand-seeded shard engine");
    }
    engine.shutdown();
}

fn sharded_fleet(shards: usize) -> Fleet {
    let tuner = Tuner::new(TunerConfig::model_only(), TuningCache::in_memory());
    let config = FleetConfig {
        shard: ShardConfig { threshold_nnz: 0, shards },
        retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
        ..FleetConfig::default()
    };
    Fleet::new(config, tuner)
}

#[test]
fn fleet_with_forced_sharding_serves_spmv_and_fused_batches() {
    let fleet = sharded_fleet(3);
    let mut a = stencil_2d(18, 18);
    randomize_values(&mut a, 13);
    let a = Arc::new(a);
    fleet.register("big", a.clone()).unwrap();
    assert!(fleet.shard_count("big").unwrap() >= 2);

    // 17 concurrent submissions: wider than the default ladder top, so
    // fused batches of every width the batcher picks are exercised.
    let inputs: Vec<Vec<f64>> = (0..17).map(|i| random_vector(a.ncols, 40 + i)).collect();
    let submissions: Vec<_> =
        inputs.iter().map(|x| fleet.submit("big", x.clone()).expect("submit")).collect();
    for (x, s) in inputs.iter().zip(submissions) {
        let resp = s.recv().expect("submission must be answered");
        assert_close(&resp.y, &a.spmv(x), "sharded fleet");
    }
    let stats = fleet.shutdown();
    assert_eq!(stats.served(), 17 * fleet_parts(&a));
}

/// Served-request accounting is per engine: a sharded entry counts one
/// served request per shard per submission.
fn fleet_parts(a: &Csr) -> usize {
    plan_ranges(a, &ShardConfig { threshold_nnz: 0, shards: 3 }).len()
}

#[test]
fn shard_fault_does_not_poison_the_fleet_and_recovery_serves() {
    let fleet = sharded_fleet(2);
    let mut a = stencil_2d(16, 16);
    randomize_values(&mut a, 17);
    let a = Arc::new(a);
    let mut b = stencil_2d(12, 12);
    randomize_values(&mut b, 19);
    let b = Arc::new(b);
    fleet.register("victim", a.clone()).unwrap();
    fleet.register("bystander", b.clone()).unwrap();
    assert!(fleet.shard_count("victim").unwrap() >= 2);

    // Healthy baseline.
    let x = random_vector(a.ncols, 23);
    assert_close(&fleet.call("victim", x.clone()).unwrap().y, &a.spmv(&x), "pre-fault");

    // Kill shard 0 mid-batch and wait for its loop to die.
    fleet.inject_shard_fault("victim", 0).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while fleet.shard_failed("victim", 0) != Some(true) {
        assert!(Instant::now() < deadline, "faulted shard worker must exit");
        std::thread::sleep(Duration::from_millis(2));
    }

    // The faulted entry reports an explicit error — never a hang, never
    // a wrong partial answer.
    let x = random_vector(a.ncols, 29);
    let err = fleet.call("victim", x).expect_err("a dead shard must surface an error");
    assert!(
        err.to_string().contains("shard"),
        "error should name the shard failure, got: {err}"
    );

    // Peers are unaffected: the other entry keeps serving correctly.
    let xb = random_vector(b.ncols, 31);
    assert_close(&fleet.call("bystander", xb.clone()).unwrap().y, &b.spmv(&xb), "bystander");

    // The journal + counter recorded the fault.
    let t = fleet.telemetry();
    assert!(t.journal.counts().iter().any(|(k, n)| *k == "shard_fault" && *n >= 1));
    assert!(t.metrics.counter(phi_spmv::telemetry::names::SHARD_FAULTS).get() >= 1);

    // Re-materialization rebuilds the dead engine from its seeds — no
    // re-search — and the entry serves correctly again.
    let (_, misses_before) = fleet.tuner_counters();
    fleet.recover("victim").unwrap();
    let (_, misses_after) = fleet.tuner_counters();
    assert_eq!(misses_after, misses_before, "recovery must not re-search");
    assert_eq!(fleet.shard_failed("victim", 0), Some(false));
    let x = random_vector(a.ncols, 37);
    assert_close(&fleet.call("victim", x.clone()).unwrap().y, &a.spmv(&x), "post-recovery");
    fleet.shutdown();
}
