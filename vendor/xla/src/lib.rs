//! Offline stub of the `xla` (PJRT) crate.
//!
//! The real crate binds the XLA PJRT C API; this build image does not ship
//! that native library, so this path dependency provides an API-compatible
//! stub: everything type-checks, and [`PjRtClient::cpu`] returns a clear
//! runtime error. The callers already degrade gracefully —
//! `rust/tests/pjrt_roundtrip.rs` and the CLI `--pjrt` flag treat a failed
//! client construction as "skip / not available" — so the AOT/PJRT path is
//! gated at runtime rather than breaking the offline build. Dropping the
//! real crate in (same package name) re-enables the path with no source
//! changes elsewhere.

use std::fmt;
use std::path::Path;

/// Stub error: always "PJRT runtime unavailable".
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA runtime unavailable (offline `xla` stub); install the real xla \
         crate and run `make artifacts` to enable the AOT path"
    )))
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// PJRT client handle. In the stub, construction always fails.
pub struct PjRtClient(());

impl PjRtClient {
    /// The CPU client — always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compiles a computation (unreachable in the stub: no client exists).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A parsed HLO module.
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parses HLO text from a file (always errors in the stub).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wraps a proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A host literal.
pub struct Literal(());

impl Literal {
    /// Builds a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Reshapes to the given dimensions (unreachable in the stub).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Unwraps a 1-tuple literal (unreachable in the stub).
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    /// Unwraps a 3-tuple literal (unreachable in the stub).
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple3")
    }

    /// Copies the buffer out as a typed vector (unreachable in the stub).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Executes with the given arguments (unreachable in the stub).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Transfers the buffer to a host literal (unreachable in the stub).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_errors_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT/XLA runtime unavailable"), "{err}");
    }

    #[test]
    fn literal_construction_is_cheap_and_typed() {
        let l = Literal::vec1(&[1.0f64, 2.0]);
        assert!(l.to_vec::<f64>().is_err());
        let _ = Literal::vec1(&[1i32, 2]);
    }
}
