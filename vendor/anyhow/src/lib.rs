//! Offline in-tree stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so this path dependency provides
//! exactly the subset of anyhow's API the workspace uses: [`Error`],
//! [`Result`], and the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! [`std::error::Error`]: that is what lets the blanket
//! `From<E: std::error::Error>` conversion (which makes `?` work on any
//! standard error) coexist with the reflexive `From<Error>` impl.

use std::fmt;

/// A type-erased error: the rendered message of the source chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Creates an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Render the whole chain inline ("outer: inner: …") so context from
        // wrapped errors is not lost when the box is flattened to a string.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Constructs an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Returns early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Returns early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_two(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // ParseIntError converts via the blanket From
        ensure!(n % 2 == 0, "{n} is odd");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse_two("4").unwrap(), 4);
        assert_eq!(parse_two("3").unwrap_err().to_string(), "3 is odd");
        assert!(parse_two("x").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_format() {
        let name = "spmv";
        let e = anyhow!("unknown kernel {name:?} ({}/{})", 1, 2);
        assert_eq!(e.to_string(), "unknown kernel \"spmv\" (1/2)");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("stopped: {flag}");
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "stopped: true");
    }

    #[test]
    fn debug_matches_display() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }
}
