"""Layer-2 JAX model: the jitted entry points that get AOT-lowered.

Each function composes the Layer-1 Pallas kernels into the computation the
Rust coordinator executes. Lowered once per shape bucket by ``aot.py``;
Python never runs at serve time.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.spmm_ell import spmm_ell  # noqa: E402
from .kernels.spmv_ell import spmv_ell  # noqa: E402


def spmv(vals, cols, x):
    """``y = A x`` — thin wrapper so the artifact is a 1-tuple."""
    return (spmv_ell(vals, cols, x),)


def spmm(vals, cols, xmat):
    """``Y = A X``."""
    return (spmm_ell(vals, cols, xmat),)


def power_iteration_step(vals, cols, x):
    """One normalized power-iteration step: ``x' = Ax / ||Ax||₂``.

    Fuses the L2 normalization into the artifact so the eigensolver
    example's hot loop is a single PJRT call. Also returns the Rayleigh
    quotient numerator ``xᵀAx`` and the norm, letting the Rust driver track
    convergence without touching the vector on the host.
    """
    y = spmv_ell(vals, cols, x)
    norm = jnp.sqrt(jnp.sum(y * y))
    rayleigh = jnp.sum(x * y)
    safe = jnp.where(norm == 0.0, 1.0, norm)
    return (y / safe, norm, rayleigh)
