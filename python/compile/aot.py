"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.json.

HLO *text* is the interchange format (NOT serialized HloModuleProto): the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction-id protos,
while the text parser reassigns ids — see /opt/xla-example/README.md.

Run once via ``make artifacts``; Rust loads the result at startup and
Python never appears on the request path.
"""

import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Shape buckets: (rows == ncols, ell width). Chosen to cover the examples
# (64²/128²/256² stencils, recommender bipartite graph) while keeping
# Rust-side compile times short.
SPMV_BUCKETS = [(4096, 8), (4096, 16), (16384, 8), (16384, 16), (65536, 8)]
SPMM_BUCKETS = [(4096, 8, 16), (16384, 8, 16), (8192, 64, 16)]
POWER_BUCKETS = [(4096, 8), (16384, 8), (65536, 8)]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (the working recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spmv(rows, width):
    vals = jax.ShapeDtypeStruct((rows, width), jnp.float64)
    cols = jax.ShapeDtypeStruct((rows, width), jnp.int32)
    x = jax.ShapeDtypeStruct((rows,), jnp.float64)
    return jax.jit(model.spmv).lower(vals, cols, x)


def lower_spmm(rows, width, k):
    vals = jax.ShapeDtypeStruct((rows, width), jnp.float64)
    cols = jax.ShapeDtypeStruct((rows, width), jnp.int32)
    xmat = jax.ShapeDtypeStruct((rows, k), jnp.float64)
    return jax.jit(model.spmm).lower(vals, cols, xmat)


def lower_power(rows, width):
    vals = jax.ShapeDtypeStruct((rows, width), jnp.float64)
    cols = jax.ShapeDtypeStruct((rows, width), jnp.int32)
    x = jax.ShapeDtypeStruct((rows,), jnp.float64)
    return jax.jit(model.power_iteration_step).lower(vals, cols, x)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifacts directory")
    parser.add_argument(
        "--quick", action="store_true", help="only the smallest bucket of each kind"
    )
    args = parser.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    spmv_buckets = SPMV_BUCKETS[:1] if args.quick else SPMV_BUCKETS
    spmm_buckets = SPMM_BUCKETS[:1] if args.quick else SPMM_BUCKETS
    power_buckets = POWER_BUCKETS[:1] if args.quick else POWER_BUCKETS

    artifacts = []

    def emit(name, kind, rows, width, ncols, k, lowered):
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        (out / path).write_text(text)
        artifacts.append(
            dict(name=name, kind=kind, rows=rows, width=width, ncols=ncols, k=k, path=path)
        )
        print(f"  wrote {path} ({len(text) / 1024:.0f} kB)")

    for rows, width in spmv_buckets:
        name = f"spmv_r{rows}_w{width}_n{rows}"
        print(f"lowering {name} ...")
        emit(name, "spmv", rows, width, rows, 1, lower_spmv(rows, width))

    for rows, width, k in spmm_buckets:
        name = f"spmm_r{rows}_w{width}_n{rows}_k{k}"
        print(f"lowering {name} ...")
        emit(name, "spmm", rows, width, rows, k, lower_spmm(rows, width, k))

    for rows, width in power_buckets:
        name = f"power_r{rows}_w{width}_n{rows}"
        print(f"lowering {name} ...")
        emit(name, "power", rows, width, rows, 1, lower_power(rows, width))

    manifest = dict(version=1, artifacts=artifacts)
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote manifest with {len(artifacts)} artifacts to {out}/manifest.json")


if __name__ == "__main__":
    main()
