"""Layer-1 Pallas kernel: ELL SpMM (k dense right-hand sides).

The paper's §5 insight — multiply several vectors at once to raise the
flop:byte ratio, keeping the k-wide accumulator in registers — maps to TPU
as: keep the (ROW_TILE, k) accumulator in VMEM scratch implied by the
reduction, gather whole X *rows* (contiguous k-vectors, no scatter) and
FMA them against broadcast values. X rows being contiguous is exactly why
the paper's SpMM avoids the `vgatherd` bottleneck; here it turns the
gather into a well-shaped (ROW_TILE, W, k) take.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Smaller row tile than SpMV: the gathered (tile, W, k) block is k× larger.
ROW_TILE = 64


def _spmm_kernel(cols_ref, x_ref, vals_ref, y_ref):
    vals = vals_ref[...]  # (T, W)
    cols = cols_ref[...]  # (T, W)
    x = x_ref[...]  # (N, K) resident
    gathered = x[cols]  # (T, W, K)
    y_ref[...] = jnp.einsum("rw,rwk->rk", vals, gathered)


@functools.partial(jax.jit, static_argnames=())
def spmm_ell(vals, cols, xmat):
    """ELL SpMM via Pallas: ``Y = A X``.

    Args:
      vals: f64[rows, width].
      cols: i32[rows, width].
      xmat: f64[n, k] — dense right-hand sides, row-major.

    Returns:
      f64[rows, k].
    """
    rows, width = vals.shape
    n, k = xmat.shape
    if rows % ROW_TILE != 0:
        raise ValueError(f"rows={rows} must be a multiple of {ROW_TILE}")
    grid = (rows // ROW_TILE,)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, width), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((ROW_TILE, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), vals.dtype),
        interpret=True,
    )(cols, xmat, vals)
