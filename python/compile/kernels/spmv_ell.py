"""Layer-1 Pallas kernel: ELL SpMV.

TPU adaptation of the paper's `vgatherd` inner loop (DESIGN.md
§Hardware-Adaptation):

* the 512-bit SIMD row group (8 doubles) becomes the ELL lane dimension;
* `vgatherd` becomes a VMEM gather ``x[cols_tile]`` — the input vector is
  held resident in VMEM while value/column tiles stream HBM→VMEM through
  the BlockSpec schedule, exactly the role the paper's cachelines play;
* rows are tiled in blocks of ``ROW_TILE`` so the (vals, cols) working set
  per grid step stays small while the reduction across the width happens
  in-register.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated in DESIGN.md §Perf from the
VMEM footprint of these BlockSpecs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 rows × width 8 × 8 B = 8 kB of values (+4 kB of
# column ids) per step — far under VMEM; x dominates the footprint.
ROW_TILE = 128


def _spmv_kernel(cols_ref, x_ref, vals_ref, y_ref):
    """One row tile: gather x by column id, multiply, reduce across width."""
    vals = vals_ref[...]  # (ROW_TILE, W)
    cols = cols_ref[...]  # (ROW_TILE, W) int32
    x = x_ref[...]  # (N,) resident in VMEM
    y_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=())
def spmv_ell(vals, cols, x):
    """ELL SpMV via Pallas: ``y = A x`` with A in padded ELL form.

    Args:
      vals: f64[rows, width] — values, zero-padded.
      cols: i32[rows, width] — column ids, sentinel-padded.
      x:    f64[n] — input vector.

    Returns:
      f64[rows].
    """
    rows, width = vals.shape
    (n,) = x.shape
    if rows % ROW_TILE != 0:
        raise ValueError(f"rows={rows} must be a multiple of {ROW_TILE}")
    grid = (rows // ROW_TILE,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, width), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((ROW_TILE, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), vals.dtype),
        interpret=True,
    )(cols, x, vals)
