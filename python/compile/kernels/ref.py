"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel must match its
reference under ``assert_allclose`` across the hypothesis shape sweep in
``python/tests/``.
"""

import jax.numpy as jnp


def spmv_ell_ref(vals, cols, x):
    """ELL SpMV reference: ``y[i] = sum_w vals[i, w] * x[cols[i, w]]``.

    Padding slots carry ``vals == 0`` so their gathered contribution
    vanishes regardless of the sentinel column.
    """
    return jnp.sum(vals * x[cols], axis=1)


def spmm_ell_ref(vals, cols, xmat):
    """ELL SpMM reference: ``Y[i, :] = sum_w vals[i, w] * X[cols[i, w], :]``."""
    return jnp.einsum("rw,rwk->rk", vals, xmat[cols])


def dense_spmv_ref(dense, x):
    """Dense oracle used to cross-check the ELL references themselves."""
    return dense @ x
