"""Pallas kernels vs pure-jnp references — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes and sparsity patterns; every case must
match ``ref.py`` under ``assert_allclose``.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spmm_ell import ROW_TILE as SPMM_TILE, spmm_ell
from compile.kernels.spmv_ell import ROW_TILE as SPMV_TILE, spmv_ell


def make_ell(rng, rows, width, n, dtype, fill=0.5, aligned_runs=False):
    """Random padded-ELL instance: (vals, cols, dense) with dense oracle."""
    vals = np.zeros((rows, width), dtype=dtype)
    cols = np.zeros((rows, width), dtype=np.int32)
    dense = np.zeros((rows, n), dtype=dtype)
    for i in range(rows):
        nnz = rng.integers(0, width + 1)
        if aligned_runs and nnz > 0:
            start = int(rng.integers(0, max(1, n - nnz)))
            chosen = np.arange(start, start + nnz) % n
            chosen = np.unique(chosen)
        else:
            chosen = np.unique(rng.integers(0, n, size=nnz))
        chosen = np.sort(chosen)
        for j, c in enumerate(chosen):
            v = rng.uniform(-1, 1) * fill
            if v == 0:
                v = 0.25
            vals[i, j] = v
            cols[i, j] = c
            dense[i, c] += v
    return vals, cols, dense


@settings(max_examples=25, deadline=None)
@given(
    rows_mult=st.integers(1, 2),
    width=st.sampled_from([8, 16, 24]),
    n=st.integers(16, 400),
    seed=st.integers(0, 2**31 - 1),
    aligned=st.booleans(),
)
def test_spmv_matches_ref_hypothesis(rows_mult, width, n, seed, aligned):
    rng = np.random.default_rng(seed)
    rows = SPMV_TILE * rows_mult
    vals, cols, dense = make_ell(rng, rows, width, n, np.float64, aligned_runs=aligned)
    x = rng.uniform(-2, 2, size=n)
    got = spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    want = ref.spmv_ell_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)
    # And both must agree with the dense oracle.
    np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=1e-10, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    width=st.sampled_from([8, 16]),
    n=st.integers(16, 200),
    k=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_matches_ref_hypothesis(width, n, k, seed):
    rng = np.random.default_rng(seed)
    rows = SPMM_TILE
    vals, cols, dense = make_ell(rng, rows, width, n, np.float64)
    xmat = rng.uniform(-2, 2, size=(n, k))
    got = spmm_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(xmat))
    want = ref.spmm_ell_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(xmat))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(got), dense @ xmat, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_spmv_dtypes(dtype):
    rng = np.random.default_rng(7)
    vals, cols, dense = make_ell(rng, SPMV_TILE, 8, 64, dtype)
    x = rng.uniform(-1, 1, size=64).astype(dtype)
    got = spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    assert np.asarray(got).dtype == dtype
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=tol, atol=tol)


def test_spmv_rejects_unaligned_rows():
    vals = jnp.zeros((100, 8))
    cols = jnp.zeros((100, 8), dtype=jnp.int32)
    x = jnp.zeros((100,))
    with pytest.raises(ValueError, match="multiple"):
        spmv_ell(vals, cols, x)


def test_all_padding_rows_give_zero():
    vals = jnp.zeros((SPMV_TILE, 8))
    cols = jnp.zeros((SPMV_TILE, 8), dtype=jnp.int32)
    x = jnp.full((32,), 5.0)
    got = spmv_ell(vals, cols, x)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(SPMV_TILE))


def test_spmv_linearity():
    """A(αx + βz) == αAx + βAz — the SpMV invariant."""
    rng = np.random.default_rng(11)
    vals, cols, _ = make_ell(rng, SPMV_TILE, 16, 128, np.float64)
    v, c = jnp.asarray(vals), jnp.asarray(cols)
    x = jnp.asarray(rng.uniform(-1, 1, 128))
    z = jnp.asarray(rng.uniform(-1, 1, 128))
    lhs = spmv_ell(v, c, 2.0 * x + 3.0 * z)
    rhs = 2.0 * spmv_ell(v, c, x) + 3.0 * spmv_ell(v, c, z)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-11, atol=1e-11)


def test_spmm_k1_column_equals_spmv():
    rng = np.random.default_rng(13)
    rows = max(SPMV_TILE, SPMM_TILE)
    vals, cols, _ = make_ell(rng, rows, 8, 96, np.float64)
    x = rng.uniform(-1, 1, size=96)
    v, c = jnp.asarray(vals), jnp.asarray(cols)
    y1 = spmv_ell(v, c, jnp.asarray(x))
    y2 = spmm_ell(v, c, jnp.asarray(x[:, None]))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2)[:, 0], rtol=1e-12)
