"""Additional algebraic properties of the Pallas kernels under hypothesis:
linearity in A, column-permutation equivariance, and SpMM decomposition —
the L1 analogs of the Rust proptests."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.spmm_ell import ROW_TILE as SPMM_TILE, spmm_ell
from compile.kernels.spmv_ell import ROW_TILE as SPMV_TILE, spmv_ell

from .test_kernels import make_ell


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(-3, 3), beta=st.floats(-3, 3))
def test_linearity_in_matrix_values(seed, alpha, beta):
    """(αA + βB)x == αAx + βBx for matrices sharing a pattern."""
    rng = np.random.default_rng(seed)
    vals, cols, _ = make_ell(rng, SPMV_TILE, 8, 64, np.float64)
    vals_b = vals * rng.uniform(0.5, 2.0)  # same pattern, scaled values
    x = jnp.asarray(rng.uniform(-1, 1, 64))
    v, vb, c = jnp.asarray(vals), jnp.asarray(vals_b), jnp.asarray(cols)
    lhs = spmv_ell(alpha * v + beta * vb, c, x)
    rhs = alpha * spmv_ell(v, c, x) + beta * spmv_ell(vb, c, x)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-9, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_column_permutation_equivariance(seed):
    """Relabeling columns and permuting x identically leaves y unchanged."""
    rng = np.random.default_rng(seed)
    n = 96
    vals, cols, _ = make_ell(rng, SPMV_TILE, 8, n, np.float64)
    x = rng.uniform(-1, 1, n)
    perm = rng.permutation(n).astype(np.int32)  # perm[old] = new
    cols_p = perm[cols]
    x_p = np.zeros_like(x)
    x_p[perm] = x
    y = spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    y_p = spmv_ell(jnp.asarray(vals), jnp.asarray(cols_p), jnp.asarray(x_p))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_p), rtol=1e-12, atol=1e-12)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([2, 8, 16]))
def test_spmm_decomposes_into_spmv_columns(seed, k):
    rng = np.random.default_rng(seed)
    rows = max(SPMV_TILE, SPMM_TILE)
    vals, cols, _ = make_ell(rng, rows, 8, 80, np.float64)
    xmat = rng.uniform(-1, 1, (80, k))
    v, c = jnp.asarray(vals), jnp.asarray(cols)
    y = spmm_ell(v, c, jnp.asarray(xmat))
    for col in range(k):
        yc = spmv_ell(v, c, jnp.asarray(xmat[:, col]))
        np.testing.assert_allclose(
            np.asarray(y)[:, col], np.asarray(yc), rtol=1e-11, atol=1e-11
        )


def test_duplicate_column_ids_accumulate():
    """ELL semantics: repeated column ids in a row sum their contributions
    (needed because COO→CSR merging happens Rust-side, but padding rows
    share the sentinel column)."""
    vals = np.zeros((SPMV_TILE, 8))
    cols = np.zeros((SPMV_TILE, 8), dtype=np.int32)
    vals[0, :3] = [1.0, 2.0, 4.0]
    cols[0, :3] = [5, 5, 5]
    x = np.zeros(16)
    x[5] = 10.0
    y = spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    assert float(y[0]) == 70.0
