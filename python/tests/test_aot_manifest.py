"""End-to-end AOT emission: run aot.py --quick into a temp dir and verify
the manifest + HLO text contract the Rust runtime depends on."""

import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--quick"],
        cwd=REPO / "python",
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_schema(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert len(arts) == 3  # one per kind in --quick mode
    kinds = {a["kind"] for a in arts}
    assert kinds == {"spmv", "spmm", "power"}
    for a in arts:
        for key in ("name", "rows", "width", "ncols", "k", "path"):
            assert key in a, f"missing {key}"
        assert a["rows"] % 8 == 0
        assert a["width"] % 8 == 0


def test_hlo_files_exist_and_are_text(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    for a in manifest["artifacts"]:
        text = (quick_artifacts / a["path"]).read_text()
        assert text.startswith("HloModule"), a["name"]
        # f64 kernels with an i32 gather-index operand.
        assert "f64[" in text
        assert "s32[" in text


def test_names_encode_shapes(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    for a in manifest["artifacts"]:
        assert f"r{a['rows']}" in a["name"]
        assert f"w{a['width']}" in a["name"]


def test_spmv_hlo_entry_signature(quick_artifacts):
    """The Rust executor passes (vals f64[r,w], cols s32[r,w], x f64[n])."""
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    spmv = next(a for a in manifest["artifacts"] if a["kind"] == "spmv")
    text = (quick_artifacts / spmv["path"]).read_text()
    r, w, n = spmv["rows"], spmv["width"], spmv["ncols"]
    params = [l for l in text.splitlines() if "parameter(" in l]
    assert len(params) >= 3
    joined = " ".join(params)
    assert f"f64[{r},{w}]" in joined
    assert f"s32[{r},{w}]" in joined
    assert f"f64[{n}]" in joined
