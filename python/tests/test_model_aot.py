"""Layer-2 model + AOT lowering smoke tests."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.spmv_ell import ROW_TILE

from .test_kernels import make_ell


def test_power_iteration_step_semantics():
    rng = np.random.default_rng(3)
    vals, cols, dense = make_ell(rng, ROW_TILE, 8, ROW_TILE, np.float64)
    # Square system: n == rows.
    x = rng.uniform(-1, 1, size=ROW_TILE)
    xn, norm, rayleigh = model.power_iteration_step(
        jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x)
    )
    y = dense @ x
    np.testing.assert_allclose(float(norm), np.linalg.norm(y), rtol=1e-12)
    np.testing.assert_allclose(float(rayleigh), x @ y, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(xn), y / np.linalg.norm(y), rtol=1e-12)


def test_power_step_zero_vector_safe():
    vals = jnp.zeros((ROW_TILE, 8))
    cols = jnp.zeros((ROW_TILE, 8), dtype=jnp.int32)
    x = jnp.zeros((ROW_TILE,))
    xn, norm, _ = model.power_iteration_step(vals, cols, x)
    assert float(norm) == 0.0
    assert np.all(np.isfinite(np.asarray(xn)))


def test_hlo_text_lowering_small_bucket():
    lowered = aot.lower_spmv(ROW_TILE, 8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text
    assert "gather" in text.lower()


def test_spmm_lowering_has_expected_shapes():
    lowered = aot.lower_spmm(ROW_TILE * 2, 8, 16)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f64[{ROW_TILE * 2},16]" in text.replace(" ", "")


def test_power_lowering_returns_three_outputs():
    lowered = aot.lower_power(ROW_TILE, 8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # tuple of (vector, scalar, scalar)
    assert text.count("f64[]") >= 2
