//! Multi-tenant serving fleet — many matrices, one memory budget, and a
//! maintenance thread that keeps the serving decisions honest.
//!
//! ```text
//! cargo run --release --example fleet [-- --requests 600 --entries 9]
//! ```
//!
//! Registers a mixed population of generated matrices (stencils, a
//! banded run matrix, power-law item graphs) under a byte budget sized
//! to hold only about half of them, so registration and traffic force
//! LRU evictions and re-materializations. Mixed SpMV/SpMM traffic then
//! skews toward a few hot entries — floods drive fused batches and walk
//! the adaptive batch width up the tuned ladder, trickles walk it back
//! down. Finally one entry's recorded GFlop/s is inflated (the
//! drift-injection hook) so the background re-tuner must confirm the
//! drift, re-tune off the serving path and hot-swap the fresh payload
//! in. Every fleet event (registrations with decisions, evictions,
//! re-materializations, width moves, the re-tune) is printed as it
//! drains.
//!
//! Tracing rides along: `--trace N` samples one request in N (default 1
//! — every request; 0 turns tracing off) and the mixed-traffic burst's
//! causal trees (request → per-shard legs → batch → kernel) are written
//! to `TRACE_fleet.json`, loadable as-is in <https://ui.perfetto.dev>
//! or `chrome://tracing`. The closing report places every entry's served
//! paths on the startup-calibrated machine roofline.

use std::sync::Arc;
use std::time::{Duration, Instant};

use phi_spmv::fleet::shard::ShardConfig;
use phi_spmv::fleet::{
    Admission, BatchConfig, Fleet, FleetConfig, Intake, RetuneConfig, TenantBudget,
};
use phi_spmv::kernels::Workload;
use phi_spmv::sched::WorkerPool;
use phi_spmv::sparse::gen::banded::{banded_runs, BandedSpec};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values, Rng};
use phi_spmv::sparse::Csr;
use phi_spmv::telemetry::{
    names, prometheus_text, validate_prometheus, MachineRoofline, Telemetry, TelemetrySnapshot,
};
use phi_spmv::tuner::{Tuner, TunerConfig, TuningCache};
use phi_spmv::util::cli::Args;

fn population(entries: usize) -> Vec<(String, Arc<Csr>)> {
    let mut mats: Vec<(String, Arc<Csr>)> = Vec::new();
    for i in 0..entries {
        let (id, mut a) = match i % 3 {
            0 => {
                let n = 40 + 6 * i;
                (format!("stencil{n}x{n}"), stencil_2d(n, n))
            }
            1 => {
                let n = 2_000 + 400 * i;
                let spec = BandedSpec {
                    n,
                    mean_row: 9.0,
                    run: 4,
                    locality: 0.05,
                    seed: 20 + i as u64,
                };
                (format!("banded{n}"), banded_runs(&spec))
            }
            _ => {
                let n = 3_000 + 500 * i;
                let spec = PowerLawSpec {
                    n,
                    nnz: 12 * n,
                    row_alpha: 1.7,
                    col_alpha: 1.5,
                    max_row: 48,
                    seed: 30 + i as u64,
                };
                (format!("powerlaw{n}"), powerlaw(&spec))
            }
        };
        randomize_values(&mut a, 100 + i as u64);
        mats.push((id, Arc::new(a)));
    }
    mats
}

fn drain_and_print(fleet: &Fleet) {
    for event in fleet.drain_events() {
        println!("  · {event}");
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get("requests", 600usize);
    let entries = args.get("entries", 9usize).max(2);
    let trace = args.get("trace", 1u64);

    let mats = population(entries);
    let total_bytes: usize = mats.iter().map(|(_, a)| a.storage_bytes()).sum();
    let budget = total_bytes / 2;
    println!(
        "fleet: {entries} matrices, {} nnz total, {} B if all warm, budget {} B",
        mats.iter().map(|(_, a)| a.nnz()).sum::<usize>(),
        total_bytes,
        budget,
    );

    // Quick-space trials keep registration snappy; the 24 h TTL is the
    // cache-decay half of online re-tuning (inert in a demo run, but it
    // shows where the knob lives).
    let tuner = Tuner::new(
        TunerConfig::quick(),
        TuningCache::in_memory().with_max_age(Duration::from_secs(24 * 3600)),
    );
    // One telemetry instance shared by every entry's engine, the tuner,
    // and the fleet's own event journal — the closing report and the
    // exported snapshot cover the whole fleet.
    let telemetry = Telemetry::new();
    // Calibrate the machine roofline before any kernel runs: achieved
    // GB/s and GFlop/s gauges, kernel-span annotations, and the closing
    // per-entry verdicts are all priced against these measured peaks.
    let roof = MachineRoofline::calibrate();
    telemetry.set_roofline(roof);
    println!(
        "roofline: peak read {:.1} GB/s | random-access latency {:.0} ns | flop ceiling \
         {:.1} GFlop/s",
        roof.peak_read_gbps, roof.random_latency_ns, roof.peak_gflops,
    );
    // 1-in-N request sampling (0 = off); traced requests carry their
    // full causal tree into TRACE_fleet.json below.
    telemetry.tracer.set_sample_every(trace);
    let fleet = Fleet::new(
        FleetConfig {
            memory_budget_bytes: budget,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            retune: RetuneConfig {
                interval: Duration::from_millis(50),
                ..RetuneConfig::default()
            },
            batch: BatchConfig { min_samples: 12, ..BatchConfig::default() },
            // Shard the larger matrices so the traces show real fan-out:
            // a request to a sharded entry fans into one span per leg.
            shard: ShardConfig { threshold_nnz: 20_000, shards: 2 },
            telemetry: telemetry.clone(),
            ..FleetConfig::default()
        },
        tuner,
    );

    println!("— registration (tuning spmv + spmm per matrix, evicting to budget) —");
    for (id, a) in &mats {
        fleet.register(id, a.clone())?;
    }
    drain_and_print(&fleet);
    println!(
        "warm payloads: {} B of {budget} B budget; {} entries registered",
        fleet.storage_bytes(),
        fleet.ids().len(),
    );

    // Mixed traffic: 70% of requests flood three hot entries (bursts →
    // fused batches → the width ladder climbs), the rest trickle across
    // the whole population (cold entries re-materialize on demand).
    println!("— mixed traffic ({requests} requests, skewed 70/30) —");
    let hot: Vec<&str> = mats.iter().take(3).map(|(id, _)| id.as_str()).collect();
    let mut rng = Rng::new(4711);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut served = 0usize;
    for r in 0..requests {
        let (id, a) = if rng.bool(0.7) {
            let id = hot[r % hot.len()];
            let a = &mats.iter().find(|(i, _)| i == id).unwrap().1;
            (id, a)
        } else {
            let (id, a) = &mats[rng.usize_below(mats.len())];
            (id.as_str(), a)
        };
        let x = random_vector(a.ncols, 5_000 + r as u64);
        pending.push(fleet.submit(id, x)?);
        // Bursts: drain every 16 submissions so hot floods fuse.
        if pending.len() >= 16 {
            served += pending.len();
            for rx in pending.drain(..) {
                rx.recv()?;
            }
        }
    }
    served += pending.len();
    for rx in pending.drain(..) {
        rx.recv()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    fleet.maintain_now();
    println!("{served} requests in {wall:.2}s = {:.0} req/s", served as f64 / wall);
    drain_and_print(&fleet);

    // Export the burst's causal traces now, while every sampled tree is
    // still complete — the drift phase below sends enough extra traffic
    // to start evicting the oldest spans from the bounded buffer.
    if trace > 0 {
        let tstats = telemetry.tracer.stats();
        telemetry.tracer.write_chrome("TRACE_fleet.json")?;
        println!(
            "traces: {} requests sampled (1-in-{trace}), {} spans, {} evicted → \
             TRACE_fleet.json (load in ui.perfetto.dev or chrome://tracing)",
            tstats.sampled, tstats.spans, tstats.dropped,
        );
    } else {
        println!("tracing off (--trace 0)");
    }

    // Drift injection: inflate one hot entry's recorded throughput so
    // the background thread must re-tune and hot-swap it under load.
    let victim = hot[0];
    println!("— drift injection on {victim} (recorded GFlop/s × 10⁶) —");
    fleet.skew_recorded_gflops(victim, Workload::Spmv, 1e6)?;
    // The adaptive ladder may have moved the batch width off its initial
    // rung, so skew the SpMM decision at whatever width is serving now.
    if let Some((_, spmm_decision)) = fleet.decisions(victim) {
        fleet.skew_recorded_gflops(victim, spmm_decision.workload, 1e6)?;
    }
    let victim_a = mats.iter().find(|(id, _)| id == victim).unwrap().1.clone();
    let deadline = Instant::now() + Duration::from_secs(10);
    while fleet.stats().retunes == 0 && Instant::now() < deadline {
        for s in 0..8u64 {
            fleet.call(victim, random_vector(victim_a.ncols, 9_000 + s))?;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    drain_and_print(&fleet);

    let stats = fleet.shutdown();
    println!("— final fleet report —");
    println!(
        "{:<16} {:>5} {:>10} {:>9} {:>22} {:>22}",
        "entry", "warm", "bytes", "served", "spmv GF [cfg]", "spmm GF [cfg]"
    );
    for e in &stats.entries {
        println!(
            "{:<16} {:>5} {:>10} {:>9} {:>14.2} [{} {}] {:>8.2} [{} {}]",
            e.id,
            if e.warm { "yes" } else { "no" },
            e.storage_bytes,
            e.spmv.served + e.spmm.served,
            e.spmv.gflops(),
            e.spmv.format,
            e.spmv.ordering,
            e.spmm.gflops(),
            e.spmm.format,
            e.spmm.workload,
        );
    }
    println!(
        "aggregate {:.2} GFlop/s over {} batches | evictions {} | rematerializations {} | \
         retunes {} | width changes {}",
        stats.gflops(),
        stats.batches(),
        stats.evictions,
        stats.rematerializations,
        stats.retunes,
        stats.width_changes,
    );
    anyhow::ensure!(stats.evictions > 0, "the budget was sized to force evictions");
    anyhow::ensure!(stats.retunes > 0, "the injected drift must have been re-tuned");

    // Per-entry roofline verdicts: modeled bytes over measured kernel
    // time, against the peaks calibrated at startup. Sparse multiplies
    // live under the roofs (latency- or bandwidth-bound) — a
    // compute-bound verdict here would mean the bytes model broke.
    println!("— roofline attribution (per entry) —");
    println!(
        "machine: read {:.1} GB/s | latency {:.0} ns | compute {:.1} GFlop/s | knee \
         {:.2} flop/B",
        roof.peak_read_gbps,
        roof.random_latency_ns,
        roof.peak_gflops,
        roof.knee_flops_per_byte(),
    );
    for e in &stats.entries {
        for (label, s, bound) in
            [("spmv", &e.spmv, &e.spmv_bound), ("spmm", &e.spmm, &e.spmm_bound)]
        {
            if s.batches == 0 {
                continue;
            }
            let gbps = roof.cap_gbps(s.achieved_gbps());
            let verdict = bound.as_deref().unwrap_or("uncalibrated");
            println!(
                "{:<16} {label}: {gbps:>6.2} GB/s ({:>4.0}% of peak), {:>6.2} GFlop/s → \
                 {verdict}",
                e.id,
                100.0 * gbps / roof.peak_read_gbps.max(1e-12),
                s.gflops().min(roof.peak_gflops),
            );
            anyhow::ensure!(
                gbps <= roof.peak_read_gbps + 1e-9,
                "achieved bandwidth must never exceed the calibrated peak"
            );
            // SpMV moves ~6 bytes per flop — it cannot reach any
            // machine's flop ceiling. (Wide fused SpMM on a scalar-only
            // host legitimately can, so only the SpMV verdict is gated.)
            anyhow::ensure!(
                label != "spmv" || verdict != "compute-bound",
                "SpMV cannot saturate the flop ceiling"
            );
        }
    }

    // Closing telemetry report: latency attribution across every entry,
    // the shared pool's utilization, and the event journal's accounting.
    println!("— telemetry —");
    let lat = telemetry.metrics.histogram(names::REQUEST_LATENCY);
    let queue_s = telemetry.metrics.histogram(names::PHASE_QUEUE).sum_s();
    let barrier_s = telemetry.metrics.histogram(names::PHASE_BARRIER).sum_s();
    let kernel_s = telemetry.metrics.histogram(names::PHASE_KERNEL).sum_s();
    let attributed = (queue_s + barrier_s + kernel_s).max(1e-12);
    println!(
        "requests {} | latency p50 {:.2} ms  p99 {:.2} ms | phases: queue {:.1}%  barrier \
         {:.1}%  kernel {:.1}%",
        lat.count(),
        lat.quantile(0.50) * 1e3,
        lat.quantile(0.99) * 1e3,
        100.0 * queue_s / attributed,
        100.0 * barrier_s / attributed,
        100.0 * kernel_s / attributed,
    );
    let probe = WorkerPool::global().probe();
    println!(
        "pool: {} workers over {} generations | utilization {:.1}% | imbalance {:.2}",
        probe.workers,
        probe.generations,
        100.0 * probe.utilization(),
        probe.imbalance(),
    );
    let mut kinds = telemetry.journal.counts();
    kinds.sort_by(|u, v| v.1.cmp(&u.1).then(u.0.cmp(v.0)));
    let top: Vec<String> =
        kinds.iter().take(6).map(|(kind, n)| format!("{kind} {n}")).collect();
    println!(
        "events: {} published, {} dropped (cap {}) | top kinds: {}",
        telemetry.journal.published(),
        telemetry.journal.dropped(),
        telemetry.journal.capacity(),
        top.join(", "),
    );

    // Export both forms and prove them well-formed before claiming OK.
    let snap = TelemetrySnapshot::capture(&telemetry);
    let back = TelemetrySnapshot::parse(&snap.to_pretty())?;
    anyhow::ensure!(
        back.json.to_string() == snap.json.to_string(),
        "telemetry snapshot must round-trip through its own parser"
    );
    snap.write("TELEMETRY_fleet.json")?;
    let prom = prometheus_text(&telemetry, Some(&probe));
    let samples = validate_prometheus(&prom)?;
    anyhow::ensure!(samples > 20, "fleet exposition suspiciously small: {samples} samples");
    std::fs::write("TELEMETRY_fleet.prom", &prom)?;
    println!("wrote TELEMETRY_fleet.json and TELEMETRY_fleet.prom ({samples} samples)");

    // The admission-controlled front door: three tenants, one with a
    // hard rate budget (the burst is admitted, the rest shed — always
    // an explicit rejection, never a hang) and one with an unmeetable
    // p99 objective (maintain() walks its batch width down a rung).
    println!("— intake: admission control & per-tenant SLOs —");
    let itelemetry = Telemetry::new();
    let ifleet = Fleet::new(
        FleetConfig {
            retune: RetuneConfig { enabled: false, ..RetuneConfig::default() },
            telemetry: itelemetry.clone(),
            ..FleetConfig::default()
        },
        Tuner::new(TunerConfig::model_only(), TuningCache::in_memory()),
    );
    let mut tenant_mats = Vec::new();
    for (i, name) in ["alpha", "bravo", "charlie"].iter().enumerate() {
        let n = 24 + 4 * i;
        let mut a = stencil_2d(n, n);
        randomize_values(&mut a, 900 + i as u64);
        let a = Arc::new(a);
        ifleet.register(name, a.clone())?;
        tenant_mats.push((name.to_string(), a));
    }
    let intake = Intake::new(ifleet, TenantBudget::unlimited());
    intake.set_budget(
        "bravo",
        TenantBudget { max_qps: 1e-9, burst: 4, ..TenantBudget::unlimited() },
    );
    intake.set_budget(
        "charlie",
        TenantBudget { p99_target: Duration::from_nanos(1), ..TenantBudget::unlimited() },
    );
    for round in 0..40u64 {
        for (name, a) in &tenant_mats {
            let x = random_vector(a.ncols, 9_500 + round);
            match intake.submit(name, x)? {
                Admission::Admitted(ticket) => {
                    ticket.recv()?;
                }
                Admission::Shed { .. } => {}
            }
        }
    }
    let width_before = intake.fleet().current_max_batch("charlie");
    intake.maintain();
    let width_after = intake.fleet().current_max_batch("charlie");
    println!(
        "{:<10} {:>9} {:>6} {:>10} {:>12} {:>6} {:>10}",
        "tenant", "admitted", "shed", "p99 ms", "target", "viol", "compliant"
    );
    for r in intake.report() {
        println!(
            "{:<10} {:>9} {:>6} {:>10.3} {:>12} {:>6} {:>10}",
            r.tenant,
            r.admitted,
            r.shed,
            r.last_p99.map(|p| p.as_secs_f64() * 1e3).unwrap_or(0.0),
            format!("{:?}", r.p99_target),
            r.violations,
            if r.compliant { "yes" } else { "NO" },
        );
    }
    println!(
        "intake totals: {} admitted, {} shed | charlie width {:?} → {:?} under p99 pressure",
        itelemetry.metrics.counter(names::INTAKE_ADMITTED).get(),
        itelemetry.metrics.counter(names::INTAKE_SHED).get(),
        width_before,
        width_after,
    );
    let report = intake.report();
    anyhow::ensure!(
        report.iter().map(|r| r.shed).sum::<u64>() > 0,
        "the rate-budgeted tenant must have shed"
    );
    anyhow::ensure!(
        report.iter().any(|r| r.violations > 0),
        "the 1 ns objective must have been violated"
    );
    let istats = intake.shutdown();
    anyhow::ensure!(istats.served() > 0, "the intake fleet must have served");

    println!("fleet OK");
    Ok(())
}
