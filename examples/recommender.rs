//! SpMM-based recommendation — the paper's motivating "server-side
//! product/friend recommendation" workload (§1, §5, ref [10]).
//!
//! ```text
//! cargo run --release --example recommender
//! ```
//!
//! A synthetic item-item co-visitation graph (power-law, as real catalogs
//! are) is multiplied against a batch of k=16 user preference vectors in
//! one SpMM — exactly the paper's point: batching vectors raises the
//! flop:byte ratio far above per-user SpMV. Scores are computed through
//! both the native kernel and (when artifacts exist) the AOT/PJRT path,
//! and the top-5 recommendations per user are printed.

use phi_spmv::kernels::{spmm_parallel, spmv_parallel};
use phi_spmv::runtime::Runtime;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::Rng;
use phi_spmv::util::bench::Bencher;

const N_ITEMS: usize = 8000;
const K_USERS: usize = 16;
const TOP: usize = 5;

fn main() -> anyhow::Result<()> {
    // Item-item similarity graph: power-law popularity, max degree capped
    // at 48 so the w64 SpMM artifact bucket fits.
    let a = powerlaw(&PowerLawSpec {
        n: N_ITEMS,
        nnz: N_ITEMS * 12,
        row_alpha: 1.7,
        col_alpha: 1.5,
        max_row: 48,
        seed: 99,
    });
    println!("item graph: {} items, {} edges", a.nrows, a.nnz());

    // K user preference vectors (sparse likes, dense representation).
    let mut rng = Rng::new(123);
    let mut x = vec![0.0f64; N_ITEMS * K_USERS];
    for u in 0..K_USERS {
        for _ in 0..20 {
            let item = rng.usize_below(N_ITEMS);
            x[item * K_USERS + u] = rng.f64_range(0.5, 1.0); // row-major X
        }
    }

    let threads = std::thread::available_parallelism()?.get();
    let bencher = Bencher::quick();

    // One SpMM scores all users at once.
    let scores = spmm_parallel(&a, &x, K_USERS, threads, Policy::Dynamic(64));
    let m = bencher.run("native spmm k=16", || {
        spmm_parallel(&a, &x, K_USERS, threads, Policy::Dynamic(64))
    });
    let spmm_gflops = m.gflops(2.0 * a.nnz() as f64 * K_USERS as f64);

    // The equivalent 16 SpMV calls, for the flop:byte comparison.
    let mut col = vec![0.0f64; N_ITEMS];
    let mv = bencher.run("16x native spmv", || {
        for u in 0..K_USERS {
            for i in 0..N_ITEMS {
                col[i] = x[i * K_USERS + u];
            }
            std::hint::black_box(spmv_parallel(&a, &col, threads, Policy::Dynamic(64)));
        }
    });
    let spmv_gflops = mv.gflops(2.0 * a.nnz() as f64 * K_USERS as f64);
    println!(
        "throughput: SpMM {spmm_gflops:.2} GFlop/s vs {K_USERS}×SpMV {spmv_gflops:.2} GFlop/s \
         ({:.2}x — the paper's §5 point)",
        spmm_gflops / spmv_gflops
    );

    // PJRT path for the same scores.
    match Runtime::from_default_dir() {
        Ok(mut rt) => match rt.spmm(&a, K_USERS) {
            Ok(exe) => {
                let y = rt.run_spmm(&exe, &x)?;
                let max_err = y
                    .iter()
                    .zip(&scores)
                    .map(|(u, v)| (u - v).abs())
                    .fold(0.0, f64::max);
                println!("pjrt spmm ({}): max |Δ| vs native = {max_err:.2e}", exe.meta.name);
                anyhow::ensure!(max_err < 1e-9, "pjrt/native mismatch");
            }
            Err(e) => println!("pjrt spmm skipped: {e}"),
        },
        Err(e) => println!("pjrt skipped ({e}); run `make artifacts`"),
    }

    // Top-5 per user (items the user already liked get masked out).
    println!("\nuser  top-{TOP} recommended items (score)");
    for u in 0..4 {
        let mut ranked: Vec<(usize, f64)> = (0..N_ITEMS)
            .filter(|i| x[i * K_USERS + u] == 0.0)
            .map(|i| (i, scores[i * K_USERS + u]))
            .collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let row: Vec<String> =
            ranked.iter().take(TOP).map(|(i, s)| format!("{i}({s:.2})")).collect();
        println!("{u:>4}  {}", row.join("  "));
    }
    println!("... ({K_USERS} users scored in one SpMM)");
    println!("recommender OK");
    Ok(())
}
