//! End-to-end driver: dominant eigenpair of a 2D Laplacian by power
//! iteration, with the **PJRT-executed Pallas kernel on the hot path**.
//!
//! ```text
//! cargo run --release --example eigensolver [-- --nx 256 --iters 400]
//! ```
//!
//! This is the repository's full-stack validation workload (DESIGN.md §6,
//! EXPERIMENTS.md §E2E): a real small problem (the paper's eigensolver
//! motivation, §5/[19]) where every multiply runs through
//! JAX/Pallas → HLO text → PJRT from the Rust coordinator, Python never
//! in the loop. Logs the residual curve and end-to-end throughput, and
//! checks the eigenvalue against the analytic Laplacian spectrum.

use phi_spmv::runtime::Runtime;
use phi_spmv::sparse::gen::random_vector;
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nx = args.get("nx", 256usize);
    let iters = args.get("iters", 400usize);

    // Problem: A = 2D 5-point Laplacian (diag 4), SPD, known spectrum:
    // λ_max = 4 + 2cos(π/(nx+1)) + 2cos(π/(ny+1)).
    let a = stencil_2d(nx, nx);
    println!("A: {}x{} Laplacian, {} nonzeros", a.nrows, a.ncols, a.nnz());

    let mut rt = Runtime::from_default_dir()?;
    let exe = rt.power_step(&a)?;
    println!(
        "pjrt artifact: {} (padded {} rows, width {}), platform {}",
        exe.meta.name,
        exe.meta.rows,
        exe.meta.width,
        rt.platform()
    );

    // Unit-norm start vector.
    let mut x = random_vector(a.nrows, 777);
    let n0 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    x.iter_mut().for_each(|v| *v /= n0);

    let expected = 4.0 + 4.0 * (std::f64::consts::PI / (nx as f64 + 1.0)).cos();
    println!("analytic λ_max = {expected:.6}");
    println!("{:>6} {:>14} {:>14} {:>10}", "iter", "rayleigh", "|Δλ|", "ms/iter");

    let t0 = std::time::Instant::now();
    let mut lambda_prev = 0.0f64;
    let mut lambda = 0.0f64;
    let mut logged = 0usize;
    for it in 1..=iters {
        // One fused PJRT call: x' = Ax/‖Ax‖, plus ‖Ax‖ and xᵀAx.
        let (xn, _norm, rayleigh) = rt.run_power_step(&exe, &x)?;
        x = xn;
        lambda = rayleigh; // x entering the step was unit-norm
        if it.is_power_of_two() || it == iters {
            let dt = t0.elapsed().as_secs_f64() * 1e3 / it as f64;
            println!(
                "{it:>6} {lambda:>14.8} {:>14.3e} {dt:>10.3}",
                (lambda - lambda_prev).abs()
            );
            logged += 1;
        }
        lambda_prev = lambda;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let flops_per_iter = 2.0 * a.nnz() as f64 + 4.0 * a.nrows as f64; // spmv + norm + dot
    println!(
        "\n{} iterations in {:.2}s — {:.2} GFlop/s sustained through PJRT",
        iters,
        elapsed,
        flops_per_iter * iters as f64 / elapsed / 1e9
    );
    println!("λ = {lambda:.8} (analytic {expected:.8}, err {:.2e})", (lambda - expected).abs());
    anyhow::ensure!(logged > 0);
    anyhow::ensure!(
        (lambda - expected).abs() < 0.05,
        "power iteration failed to approach the dominant eigenvalue"
    );
    println!("eigensolver OK");
    Ok(())
}
