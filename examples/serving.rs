//! Throughput-oriented SpMV serving — the paper's §1 motivation
//! ("throughput oriented server-side code for SpMV/SpMM-based services
//! such as product/friend recommendation") as a running system.
//!
//! ```text
//! cargo run --release --example serving [-- --requests 400 --rate 2000]
//! ```
//!
//! A Poisson stream of recommendation requests hits the batching
//! coordinator, which fuses up to 16 of them into one SpMM. Reports
//! throughput, mean batch size, P50/P95/P99 latency, and the storage
//! format + workload each path actually executed — then repeats with
//! batching disabled (max_batch = 1) to show the SpMM batching win, and
//! once more under the auto-tuner's *pair* of decisions: one tuned for
//! SpMV (lone requests) and one tuned for SpMM at the batch width (fused
//! batches). At shutdown the measured batch-path throughput is compared
//! against the cached SpMM decision's recorded GFlop/s and the entry is
//! invalidated if it drifted — the online re-tuning hook.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use phi_spmv::coordinator::server::{percentile, PathSpec, ServerConfig, ServerStats, SpmvServer};
use phi_spmv::kernels::{IsaLevel, Workload};
use phi_spmv::sched::WorkerPool;
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::{randomize_values, Rng};
use phi_spmv::telemetry::{names, MachineRoofline, Telemetry, TelemetrySnapshot};
use phi_spmv::tuner::{Tuner, TunerConfig, TuningCache};
use phi_spmv::util::cli::Args;
use phi_spmv::util::json::Json;

fn run(
    label: &str,
    a: &Arc<phi_spmv::sparse::Csr>,
    cfg: ServerConfig,
    requests: usize,
    rate_hz: f64,
) -> anyhow::Result<ServerStats> {
    let server = SpmvServer::start(a.clone(), cfg);
    let client = server.client();
    let mut rng = Rng::new(4242);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        // Sparse user profile as a dense vector.
        let mut x = vec![0.0f64; a.ncols];
        for _ in 0..16 {
            x[rng.usize_below(a.ncols)] = rng.f64_range(0.25, 1.0);
        }
        pending.push(client.submit(x)?);
        // Poisson arrivals.
        let gap = -rng.f64().max(1e-12).ln() / rate_hz;
        std::thread::sleep(Duration::from_secs_f64(gap.min(0.01)));
    }
    let mut latencies: Vec<Duration> = Vec::with_capacity(requests);
    let mut batch_sum = 0usize;
    for rx in pending {
        let resp = rx.recv()?;
        latencies.push(resp.latency);
        batch_sum += resp.batch_size;
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort();
    let stats = server.shutdown();
    println!(
        "{label:<14} {requests} reqs in {wall:.2}s = {:.0} req/s | mean batch {:.2} | \
         P50 {:.2} ms  P95 {:.2} ms  P99 {:.2} ms | spmv {:.2} GF [{} {}] | \
         spmm {:.2} GF [{} {} {}]",
        requests as f64 / wall,
        batch_sum as f64 / requests as f64,
        percentile(&latencies, 0.50).as_secs_f64() * 1e3,
        percentile(&latencies, 0.95).as_secs_f64() * 1e3,
        percentile(&latencies, 0.99).as_secs_f64() * 1e3,
        stats.spmv.gflops(),
        stats.spmv.format,
        stats.spmv.ordering,
        stats.spmm.gflops(),
        stats.spmm.format,
        stats.spmm.ordering,
        stats.spmm.workload,
    );
    Ok(stats)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get("requests", 400usize);
    let rate = args.get("rate", 2000.0f64);
    let cache_path = args.get_str("cache").unwrap_or("serving_cache.json").to_string();
    let threads = std::thread::available_parallelism()?.get();

    let mut a = powerlaw(&PowerLawSpec {
        n: 20_000,
        nnz: 240_000,
        row_alpha: 1.7,
        col_alpha: 1.5,
        max_row: 64,
        seed: 7,
    });
    randomize_values(&mut a, 8);
    let a = Arc::new(a);
    println!(
        "item graph: {} items, {} edges; offered load {rate:.0} req/s",
        a.nrows,
        a.nnz()
    );

    // One shared telemetry instance across all three runs, so the
    // closing report attributes the whole example's latency.
    let telemetry = Telemetry::new();
    // Calibrate the machine roofline up front: every batch's kernel
    // window is then priced into achieved GB/s and GFlop/s against these
    // measured peaks, and the closing report places each served path on
    // the roofs — the paper's microbenchmark-vs-kernel methodology.
    let roof = MachineRoofline::calibrate();
    telemetry.set_roofline(roof);
    println!(
        "roofline: peak read {:.1} GB/s | random-access latency {:.0} ns | flop ceiling \
         {:.1} GFlop/s (knee {:.2} flop/B)",
        roof.peak_read_gbps,
        roof.random_latency_ns,
        roof.peak_gflops,
        roof.knee_flops_per_byte(),
    );
    let with_threads = PathSpec { threads, ..PathSpec::default() };
    run(
        "batched k≤16",
        &a,
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            spmv: with_threads.clone(),
            telemetry: telemetry.clone(),
            ..ServerConfig::default()
        },
        requests,
        rate,
    )?;
    run(
        "unbatched",
        &a,
        ServerConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            spmv: with_threads,
            telemetry: telemetry.clone(),
            ..ServerConfig::default()
        },
        requests,
        rate,
    )?;

    // The auto-tuned server, one decision per workload: lone requests run
    // the SpMV decision, fused batches the SpMM decision tuned at the
    // serving batch width — what each path executes (format *and*
    // workload) is read back from ServerStats, not from the decisions.
    // The cache is persistent, so a drift invalidation below really does
    // make the next boot re-tune.
    let mut tuner =
        Tuner::new(TunerConfig::default(), TuningCache::load(Path::new(&cache_path))?);
    let spmv_decision = tuner.tune("recsys-items", &a)?;
    let spmm_decision = tuner.tune_workload("recsys-items", &a, Workload::Spmm { k: 16 })?;
    println!("tuner decision (spmv): {spmv_decision}");
    println!("tuner decision (spmm): {spmm_decision}");
    let stats = run(
        "tuned pair",
        &a,
        ServerConfig {
            telemetry: telemetry.clone(),
            ..ServerConfig::tuned_pair(&spmv_decision, &spmm_decision)
        },
        requests,
        rate,
    )?;

    // Online re-tuning hook: compare what the batch path measured against
    // what the cached decision promised; a drifted entry is dropped so the
    // next boot re-tunes under current conditions. The promised figure was
    // trialed at exactly k = 16, and fused throughput falls with narrower
    // batches, so the comparison only runs when the serving batches came
    // close to the tuned width — otherwise a lightly-loaded server would
    // invalidate a perfectly good decision on every shutdown.
    let measured = stats.spmm.gflops();
    let mean_fused = if stats.spmm.batches == 0 {
        0.0
    } else {
        stats.spmm.served as f64 / stats.spmm.batches as f64
    };
    // The gate is deliberately strict (3/4 of the tuned width): the
    // promised figure is a *min-of-iterations* trial at full k, while the
    // measurement is a serving *average* over mixed widths — comparing
    // from too far below full width would invalidate healthy entries.
    let tuned_k = spmm_decision.workload.k();
    if mean_fused < tuned_k as f64 * 0.75 {
        println!(
            "drift check skipped: mean fused batch {mean_fused:.1} is too narrow to \
             compare against the k={tuned_k} trial figure ({:.2} GFlop/s)",
            spmm_decision.gflops
        );
    } else {
        // The key is rebuilt from the decision's own workload, so the
        // tune call and the drift check cannot desynchronize.
        let key = tuner.key("recsys-items", &a, spmm_decision.workload);
        if tuner.cache.invalidate_if_drifted(&key, measured, 0.5) {
            tuner.cache.save()?;
            println!(
                "drift: batch path measured {measured:.2} GFlop/s vs promised {:.2} — \
                 entry dropped from {cache_path}, next boot re-tunes",
                spmm_decision.gflops
            );
        } else {
            println!(
                "no drift: batch path measured {measured:.2} GFlop/s against promised {:.2} \
                 (tolerance 50%, mean fused batch {mean_fused:.1})",
                spmm_decision.gflops
            );
        }
    }
    // Closing telemetry report: the histograms the engines recorded into
    // the shared instance explain where every request's latency went.
    println!("— telemetry (all three runs) —");
    let lat = telemetry.metrics.histogram(names::REQUEST_LATENCY);
    println!(
        "requests {} | batches {} | latency mean {:.2} ms  p50 {:.2}  p90 {:.2}  p99 {:.2}  \
         p999 {:.2}",
        telemetry.metrics.counter(names::REQUESTS_SERVED).get(),
        telemetry.metrics.counter(names::BATCHES_EXECUTED).get(),
        lat.mean_s() * 1e3,
        lat.quantile(0.50) * 1e3,
        lat.quantile(0.90) * 1e3,
        lat.quantile(0.99) * 1e3,
        lat.quantile(0.999) * 1e3,
    );
    let queue_s = telemetry.metrics.histogram(names::PHASE_QUEUE).sum_s();
    let barrier_s = telemetry.metrics.histogram(names::PHASE_BARRIER).sum_s();
    let kernel_s = telemetry.metrics.histogram(names::PHASE_KERNEL).sum_s();
    let attributed = queue_s + barrier_s + kernel_s;
    let wall = lat.sum_s();
    println!(
        "phase attribution: queue {:.1}%  barrier {:.1}%  kernel {:.1}% of {attributed:.3} s \
         ({:.1}% of the {wall:.3} s wall latency)",
        100.0 * queue_s / attributed.max(1e-12),
        100.0 * barrier_s / attributed.max(1e-12),
        100.0 * kernel_s / attributed.max(1e-12),
        100.0 * attributed / wall.max(1e-12),
    );
    anyhow::ensure!(
        (wall - attributed).abs() <= (0.10 * wall).max(5e-3),
        "phase spans must sum to the wall latency: attributed {attributed:.3} s vs {wall:.3} s"
    );
    let probe = WorkerPool::global().probe();
    println!(
        "pool: {} workers over {} generations | utilization {:.1}% | imbalance {:.2} | \
         caller busy {:.3} s",
        probe.workers,
        probe.generations,
        100.0 * probe.utilization(),
        probe.imbalance(),
        probe.caller_busy_s,
    );
    println!(
        "isa: {} ({} lanes) | pinning: {}",
        IsaLevel::detect(),
        IsaLevel::detect().lanes(),
        if probe.pinned {
            format!("{} of {} workers pinned", probe.pinned_workers, probe.workers)
        } else {
            "off (set PALLAS_PIN=1, PALLAS_PLACEMENT=compact|scatter)".to_string()
        },
    );
    let snap = TelemetrySnapshot::capture(&telemetry);
    let back = TelemetrySnapshot::parse(&snap.to_pretty())?;
    anyhow::ensure!(
        back.json.to_string() == snap.json.to_string(),
        "telemetry snapshot must round-trip through its own parser"
    );

    // Where did the bytes go? Every format family the three runs served,
    // placed on the calibrated roofline. The exported gauges are capped
    // at the calibrated peaks, so "achieved ≤ peak" is structural — the
    // ensure catches a broken bytes model, not a fast machine.
    println!("— roofline attribution —");
    match snap.json.get("roofline").and_then(|r| r.get("paths")) {
        Some(Json::Obj(paths)) if !paths.is_empty() => {
            for (family, p) in paths {
                let gbps = p.get("achieved_gbps").and_then(Json::as_f64).unwrap_or(0.0);
                let gflops = p.get("achieved_gflops").and_then(Json::as_f64).unwrap_or(0.0);
                let bound = p.get("bound").and_then(Json::as_str).unwrap_or("?");
                println!(
                    "{family:<6} {gbps:>7.2} GB/s of {:.1} peak | {gflops:>7.2} GFlop/s of \
                     {:.1} ceiling → {bound}",
                    roof.peak_read_gbps, roof.peak_gflops,
                );
                anyhow::ensure!(
                    gbps <= roof.peak_read_gbps + 1e-9,
                    "achieved bandwidth must never exceed the calibrated peak"
                );
            }
        }
        _ => println!("no kernel windows recorded"),
    }

    snap.write("TELEMETRY_serving.json")?;
    println!("wrote TELEMETRY_serving.json");
    println!("serving OK");
    Ok(())
}
