//! Throughput-oriented SpMV serving — the paper's §1 motivation
//! ("throughput oriented server-side code for SpMV/SpMM-based services
//! such as product/friend recommendation") as a running system.
//!
//! ```text
//! cargo run --release --example serving [-- --requests 400 --rate 2000]
//! ```
//!
//! A Poisson stream of recommendation requests hits the batching
//! coordinator, which fuses up to 16 of them into one SpMM. Reports
//! throughput, mean batch size, P50/P95/P99 latency, and the storage
//! format the batches actually executed in — then repeats with batching
//! disabled (max_batch = 1) to show the SpMM batching win, and once more
//! under the auto-tuner's decision (which the server now executes for
//! real instead of silently serving CSR).

use std::sync::Arc;
use std::time::Duration;

use phi_spmv::coordinator::server::{percentile, ServerConfig, SpmvServer};
use phi_spmv::sparse::gen::powerlaw::{powerlaw, PowerLawSpec};
use phi_spmv::sparse::gen::{randomize_values, Rng};
use phi_spmv::util::cli::Args;

fn run(
    label: &str,
    a: &Arc<phi_spmv::sparse::Csr>,
    cfg: ServerConfig,
    requests: usize,
    rate_hz: f64,
) -> anyhow::Result<()> {
    let server = SpmvServer::start(a.clone(), cfg);
    let client = server.client();
    let mut rng = Rng::new(4242);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        // Sparse user profile as a dense vector.
        let mut x = vec![0.0f64; a.ncols];
        for _ in 0..16 {
            x[rng.usize_below(a.ncols)] = rng.f64_range(0.25, 1.0);
        }
        pending.push(client.submit(x)?);
        // Poisson arrivals.
        let gap = -rng.f64().max(1e-12).ln() / rate_hz;
        std::thread::sleep(Duration::from_secs_f64(gap.min(0.01)));
    }
    let mut latencies: Vec<Duration> = Vec::with_capacity(requests);
    let mut batch_sum = 0usize;
    for rx in pending {
        let resp = rx.recv()?;
        latencies.push(resp.latency);
        batch_sum += resp.batch_size;
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort();
    let stats = server.shutdown();
    println!(
        "{label:<14} {requests} reqs in {wall:.2}s = {:.0} req/s | mean batch {:.2} | \
         P50 {:.2} ms  P95 {:.2} ms  P99 {:.2} ms | kernel {:.2} GFlop/s | format {}",
        requests as f64 / wall,
        batch_sum as f64 / requests as f64,
        percentile(&latencies, 0.50).as_secs_f64() * 1e3,
        percentile(&latencies, 0.95).as_secs_f64() * 1e3,
        percentile(&latencies, 0.99).as_secs_f64() * 1e3,
        stats.flops / stats.compute_s.max(1e-9) / 1e9,
        stats.format,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get("requests", 400usize);
    let rate = args.get("rate", 2000.0f64);
    let threads = std::thread::available_parallelism()?.get();

    let mut a = powerlaw(&PowerLawSpec {
        n: 20_000,
        nnz: 240_000,
        row_alpha: 1.7,
        col_alpha: 1.5,
        max_row: 64,
        seed: 7,
    });
    randomize_values(&mut a, 8);
    let a = Arc::new(a);
    println!(
        "item graph: {} items, {} edges; offered load {rate:.0} req/s",
        a.nrows,
        a.nnz()
    );

    run(
        "batched k≤16",
        &a,
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            threads,
            ..ServerConfig::default()
        },
        requests,
        rate,
    )?;
    run(
        "unbatched",
        &a,
        ServerConfig { max_batch: 1, max_wait: Duration::ZERO, threads, ..ServerConfig::default() },
        requests,
        rate,
    )?;

    // The auto-tuned server: whatever (format, schedule, threads) the
    // tuner picks is what the serve loop executes — the printed `format`
    // column is read back from ServerStats, not from the decision.
    let mut tuner = phi_spmv::tuner::Tuner::in_memory();
    let decision = tuner.tune("recsys-items", &a)?;
    println!("tuner decision: {decision}");
    run("tuned", &a, ServerConfig::tuned(&decision), requests, rate)?;
    println!("serving OK");
    Ok(())
}
