//! Regenerates every table and figure of the paper in one run.
//!
//! ```text
//! cargo run --release --example paper_figures [-- --scale 0.25 --out results]
//! ```
//!
//! Equivalent to `phi-spmv all`; kept as an example so `cargo run
//! --example` users see the full reproduction surface. At `--scale 1.0`
//! the matrices match Table 1's sizes exactly (a few GB of RAM and some
//! patience); the default 0.25 preserves every per-row statistic.

use phi_spmv::coordinator::{Ctx, Experiment, ALL_EXPERIMENTS};
use phi_spmv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let ctx = Ctx {
        scale: args.get("scale", 0.25f64).clamp(1e-4, 1.0),
        out_dir: args.get_str("out").unwrap_or("results").into(),
        verbose: true,
        ..Ctx::default()
    };
    let t0 = std::time::Instant::now();
    for id in ALL_EXPERIMENTS {
        let r = Experiment::run(id, &ctx)?;
        println!("{}", r.render());
        r.save(&ctx.out_dir)?;
    }
    println!(
        "regenerated {} experiments into {} in {:.1}s (scale {})",
        ALL_EXPERIMENTS.len(),
        ctx.out_dir.display(),
        t0.elapsed().as_secs_f64(),
        ctx.scale
    );
    Ok(())
}
