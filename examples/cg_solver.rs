//! Conjugate-gradient solve of a 2D Poisson system — the application the
//! paper's reference [4] benchmarks on Xeon Phi (SpMV-dominated CG).
//!
//! ```text
//! cargo run --release --example cg_solver [-- --nx 192 --tol 1e-8]
//! ```
//!
//! The A·p product inside the CG loop uses the native parallel SpMV under
//! `dynamic,64`; everything else is level-1 vector work. Reports the
//! residual curve, iteration count and sustained SpMV GFlop/s.

use phi_spmv::kernels::spmv_parallel_into;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::util::cli::Args;

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(u, v)| u * v).sum()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nx = args.get("nx", 192usize);
    let tol = args.get("tol", 1e-8f64);
    let max_iters = args.get("max-iters", 2000usize);
    let threads = std::thread::available_parallelism()?.get();

    // SPD system: 5-point Laplacian; manufactured solution x* = 1.
    let a = stencil_2d(nx, nx);
    let n = a.nrows;
    let x_star = vec![1.0f64; n];
    let b = a.spmv(&x_star);
    println!("A: {n}x{n} Laplacian ({} nnz), solving Ax = A·1", a.nnz());

    // CG with x0 = 0.
    let mut x = vec![0.0f64; n];
    let mut r = b.clone();
    let mut p = r.clone();
    let mut ap = vec![0.0f64; n];
    let mut rs = dot(&r, &r);
    let rs0 = rs.sqrt();

    let t0 = std::time::Instant::now();
    let mut spmv_count = 0usize;
    let mut iters = 0usize;
    println!("{:>6} {:>14}", "iter", "rel residual");
    for it in 1..=max_iters {
        spmv_parallel_into(&a, &p, &mut ap, threads, Policy::Dynamic(64));
        spmv_count += 1;
        let alpha = rs / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        if it.is_power_of_two() {
            println!("{it:>6} {:>14.3e}", rs_new.sqrt() / rs0);
        }
        if rs_new.sqrt() <= tol * rs0 {
            iters = it;
            println!("{it:>6} {:>14.3e}  (converged)", rs_new.sqrt() / rs0);
            break;
        }
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        iters = it;
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // Verify against the manufactured solution.
    let max_err = x.iter().zip(&x_star).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
    println!(
        "\nconverged in {iters} iterations, {elapsed:.2}s; max |x - x*| = {max_err:.2e}"
    );
    println!(
        "SpMV throughput inside CG: {:.2} GFlop/s ({spmv_count} multiplies, {threads} threads)",
        2.0 * a.nnz() as f64 * spmv_count as f64 / elapsed / 1e9
    );
    anyhow::ensure!(max_err < 1e-5, "CG did not converge to the manufactured solution");
    println!("cg_solver OK");
    Ok(())
}
