//! Quickstart: generate a matrix, run SpMV three ways, check they agree.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the three execution paths of the library:
//! 1. the serial CSR oracle,
//! 2. the native multithreaded kernel (the paper's OpenMP analog),
//! 3. the AOT path: JAX/Pallas kernel lowered to HLO, executed via PJRT.

use phi_spmv::kernels::spmv_parallel;
use phi_spmv::runtime::Runtime;
use phi_spmv::sched::Policy;
use phi_spmv::sparse::gen::stencil::stencil_2d;
use phi_spmv::sparse::gen::{random_vector, randomize_values};
use phi_spmv::sparse::stats::{ucld, MatrixStats};
use phi_spmv::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    // 1. A small 5-point stencil (the paper's mesh_2048, scaled down).
    let mut a = stencil_2d(64, 64);
    randomize_values(&mut a, 1);
    let st = MatrixStats::compute("mesh_64", &a);
    println!(
        "matrix: {} ({} rows, {} nnz, {:.2} nnz/row, UCLD {:.3})",
        st.name,
        st.nrows,
        st.nnz,
        st.nnz_per_row,
        ucld(&a)
    );

    let x = random_vector(a.ncols, 2);
    let flops = 2.0 * a.nnz() as f64;

    // 2. Serial oracle.
    let want = a.spmv(&x);

    // 3. Native parallel kernel (dynamic,64 — the paper's best policy).
    let threads = std::thread::available_parallelism()?.get();
    let got = spmv_parallel(&a, &x, threads, Policy::Dynamic(64));
    assert_eq!(got.len(), want.len());
    let max_err = got.iter().zip(&want).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
    println!("native parallel vs serial: max |Δ| = {max_err:.2e}");

    let bencher = Bencher::quick();
    let m = bencher.run("native spmv", || spmv_parallel(&a, &x, threads, Policy::Dynamic(64)));
    println!("native: {:.2} GFlop/s ({} threads)", m.gflops(flops), threads);

    // 4. AOT/PJRT path (JAX+Pallas lowered at build time by `make artifacts`).
    match Runtime::from_default_dir() {
        Ok(mut rt) => {
            let exe = rt.spmv(&a)?;
            println!(
                "pjrt: platform={}, bucket={} ({}x{} w{})",
                rt.platform(),
                exe.meta.name,
                exe.meta.rows,
                exe.meta.ncols,
                exe.meta.width
            );
            let y = rt.run_spmv(&exe, &x)?;
            let max_err =
                y.iter().zip(&want).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
            println!("pjrt vs serial: max |Δ| = {max_err:.2e}");
            assert!(max_err < 1e-10, "PJRT result mismatch");
            let mp = bencher.run("pjrt spmv", || rt.run_spmv(&exe, &x).unwrap());
            println!("pjrt: {:.2} GFlop/s", mp.gflops(flops));
        }
        Err(e) => println!("pjrt path skipped ({e}); run `make artifacts`"),
    }

    println!("quickstart OK");
    Ok(())
}
