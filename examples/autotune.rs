//! Auto-tuning demo: pick the fast (format, schedule, threads) for a
//! matrix, persist the decision, and prove the cache works.
//!
//! ```text
//! cargo run --release --example autotune \
//!     [-- --matrix scircuit --scale 0.05 --cache autotune_cache.json]
//! ```
//!
//! Pass 1 loads (or creates) the cache file, misses, searches the pruned
//! candidate space with short empirical trials, persists the decision and
//! verifies the tuned SpMV against the serial CSR oracle. Pass 2 reloads
//! the cache from disk — as a fresh process would — and must answer from
//! it without searching. Running the binary twice demonstrates the same
//! persistence across processes.

use std::path::Path;
use std::time::Instant;

use phi_spmv::sparse::gen::{paper_suite, random_vector, randomize_values};
use phi_spmv::tuner::{Prepared, Tuner, TunerConfig, TuningCache};
use phi_spmv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.get_str("matrix").unwrap_or("scircuit").to_string();
    let scale = args.get("scale", 0.05f64).clamp(1e-4, 1.0);
    let cache_path = args.get_str("cache").unwrap_or("autotune_cache.json").to_string();

    let suite = paper_suite();
    let entry = suite
        .iter()
        .find(|e| e.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown matrix {name:?}; see `phi-spmv table1`"))?;
    let mut a = entry.generate_scaled(scale);
    randomize_values(&mut a, entry.id as u64);
    println!("matrix {name}: {} rows, {} nonzeros (scale {scale})", a.nrows, a.nnz());

    let x = random_vector(a.ncols, 17);
    let oracle = a.spmv(&x);

    for pass in 1..=2 {
        // Reload from disk each pass: pass 2 sees exactly what a fresh
        // process would.
        let cache = TuningCache::load(Path::new(&cache_path))?;
        let mut tuner = Tuner::new(TunerConfig { verbose: true, ..TunerConfig::default() }, cache);

        let t0 = Instant::now();
        let decision = tuner.tune(&name, &a)?;
        let tune_ms = t0.elapsed().as_secs_f64() * 1e3;
        let outcome = if tuner.cache.hits > 0 {
            "cache HIT (search skipped)"
        } else {
            "cache miss → decision persisted"
        };
        println!("pass {pass}: tuned in {tune_ms:.1} ms — {outcome}");
        println!("pass {pass}: chose {decision}");

        let prepared = Prepared::new(&a, decision.candidate());
        let y = prepared.spmv(&x);
        let mut max_err = 0.0f64;
        for (u, v) in y.iter().zip(&oracle) {
            max_err = max_err.max((u - v).abs() / (1.0 + v.abs()));
        }
        anyhow::ensure!(max_err < 1e-9, "tuned SpMV diverged from oracle: {max_err}");
        println!("pass {pass}: tuned SpMV matches the serial CSR oracle (max rel err {max_err:.2e})");
    }

    println!("autotune OK (cache file: {cache_path})");
    Ok(())
}
